"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret mode
(assignment deliverable c: per-kernel allclose against ref.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def keys(n, seed=0):
    return jax.random.split(jax.random.PRNGKey(seed), n)


# --------------------------------------------------------------------------- #
# l1_topk2 — batched L1 distance + top-2 margins (the utility test).
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,K,D", [
    (1, 2, 8), (7, 5, 33), (64, 16, 96), (100, 16, 150), (128, 32, 256),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_l1_topk2_sweep(B, K, D, dtype):
    k1, k2 = keys(2, seed=B * 7 + K)
    x = jax.random.normal(k1, (B, D), dtype=jnp.float32).astype(dtype)
    c = jax.random.normal(k2, (K, D), dtype=jnp.float32).astype(dtype)
    d1, d2, idx = ops.l1_topk2(x.astype(jnp.float32), c.astype(jnp.float32))
    rd1, rd2, ridx = ref.l1_topk2_ref(
        x.astype(jnp.float32), c.astype(jnp.float32)
    )
    np.testing.assert_allclose(d1, rd1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d2, rd2, rtol=1e-5, atol=1e-5)
    assert (np.asarray(idx) == np.asarray(ridx)).all()
    assert bool((d2 >= d1).all())


def test_l1_topk2_identical_point_zero_distance():
    c = jnp.asarray(np.random.default_rng(0).normal(size=(4, 16)),
                    jnp.float32)
    d1, d2, idx = ops.l1_topk2(c[1:2], c)
    assert float(d1[0]) == pytest.approx(0.0, abs=1e-6)
    assert int(idx[0]) == 1


# --------------------------------------------------------------------------- #
# pairwise_l1 — all-pairs distance matrix (siamese training).
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B1,B2,D", [
    (4, 4, 16), (48, 72, 200), (33, 17, 101), (128, 16, 64),
])
def test_pairwise_l1_sweep(B1, B2, D):
    k1, k2 = keys(2, seed=B1 + B2)
    a = jax.random.normal(k1, (B1, D))
    b = jax.random.normal(k2, (B2, D))
    got = ops.pairwise_l1(a, b)
    want = ref.pairwise_l1_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pairwise_l1_self_diagonal_zero():
    a = jax.random.normal(jax.random.PRNGKey(3), (12, 40))
    d = np.asarray(ops.pairwise_l1(a, a))
    np.testing.assert_allclose(np.diag(d), 0.0, atol=1e-6)
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------- #
# centroid_update — weighted-average semi-supervised adaptation.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("K,B,D,w", [
    (4, 16, 32, 8.0), (8, 40, 64, 32.0), (16, 7, 150, 1.0), (3, 1, 9, 100.0),
])
def test_centroid_update_sweep(K, B, D, w):
    k1, k2, k3 = keys(3, seed=K * B)
    cents = jax.random.normal(k1, (K, D))
    feats = jax.random.normal(k2, (B, D))
    assign = jax.random.randint(k3, (B,), 0, K)
    got = ops.centroid_update(cents, feats, assign, w)
    want = ref.centroid_update_ref(cents, feats, assign, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_centroid_update_empty_cluster_unchanged():
    cents = jnp.ones((4, 8))
    feats = jnp.zeros((3, 8))
    assign = jnp.asarray([0, 0, 1])
    out = np.asarray(ops.centroid_update(cents, feats, assign, 10.0))
    np.testing.assert_allclose(out[2], 1.0)  # untouched clusters
    np.testing.assert_allclose(out[3], 1.0)
    assert (out[0] < 1.0).all()  # pulled toward the zeros


def test_centroid_update_weight_limit():
    """weight -> inf keeps centroids; weight -> 0 jumps to the batch mean."""
    k1, k2 = keys(2, 9)
    cents = jax.random.normal(k1, (2, 8))
    feats = jax.random.normal(k2, (6, 8))
    assign = jnp.zeros((6,), jnp.int32)
    heavy = np.asarray(ops.centroid_update(cents, feats, assign, 1e9))
    np.testing.assert_allclose(heavy, np.asarray(cents), rtol=1e-4, atol=1e-4)
    light = np.asarray(ops.centroid_update(cents, feats, assign, 1e-9))
    np.testing.assert_allclose(
        light[0], np.asarray(feats.mean(0)), rtol=1e-3, atol=1e-3
    )


# --------------------------------------------------------------------------- #
# rglru_scan — blocked diagonal linear recurrence.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,W", [
    (1, 8, 16), (4, 64, 96), (2, 100, 33), (8, 17, 128),
])
def test_rglru_scan_sweep(B, S, W):
    ks = keys(3, seed=B * S)
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.7, maxval=0.999)
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    h, hl = ops.rglru_scan(a, b, h0)
    rh, rhl = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(h, rh, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hl, rhl, rtol=1e-4, atol=1e-5)


def test_rglru_scan_matches_model_reference():
    """Kernel agrees with the model's associative-scan path end to end."""
    from repro.models import rglru as rg

    ks = keys(3, seed=11)
    B, S, W = 2, 32, 64
    x = jax.random.normal(ks[0], (B, S, W)) * 0.5
    p = rg.init_rglru(ks[1], W, jnp.float32)
    y_model, h_model = rg.rglru_seq(p, x)
    a, b = rg._gates(p, x)
    y_kernel, h_kernel = ops.rglru_scan(a, b, jnp.zeros((B, W)))
    np.testing.assert_allclose(
        np.asarray(y_model, np.float32), np.asarray(y_kernel),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(h_model, h_kernel, rtol=1e-4, atol=1e-5)


# --------------------------------------------------------------------------- #
# decode_gqa — one-token attention against a ring-buffer KV cache.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,H,KV,hd,C", [
    (1, 4, 4, 16, 32), (4, 8, 2, 32, 128), (2, 16, 1, 64, 64),
    (3, 8, 8, 32, 96),
])
@pytest.mark.parametrize("window", [0, 16])
def test_decode_gqa_sweep(B, H, KV, hd, C, window):
    ks = keys(4, seed=B * H + C)
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, C, KV, hd))
    vc = jax.random.normal(ks[2], (B, C, KV, hd))
    pos = jax.random.randint(ks[3], (B,), 1, C + 1)
    slot = jnp.stack(
        [jnp.where(jnp.arange(C) < p, jnp.arange(C), -1) for p in pos]
    )
    got = ops.decode_gqa(q, kc, vc, slot, pos, window=window)
    want = ref.decode_gqa_ref(q, kc, vc, slot, pos, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_decode_gqa_matches_model_attention():
    from repro.models.attention import decode_attention

    ks = keys(4, seed=5)
    B, H, KV, hd, C = 2, 8, 4, 32, 64
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, C, KV, hd))
    vc = jax.random.normal(ks[2], (B, C, KV, hd))
    pos = jnp.asarray([40, 64])
    slot = jnp.stack(
        [jnp.where(jnp.arange(C) < p, jnp.arange(C), -1) for p in pos]
    )
    got = ops.decode_gqa(q, kc, vc, slot, pos)
    want = decode_attention(q, kc, vc, slot, pos)
    np.testing.assert_allclose(
        got, np.asarray(want, np.float32), rtol=1e-4, atol=1e-5
    )


# --------------------------------------------------------------------------- #
# flash_attention — fused online-softmax GQA forward (the §Perf P1 target).
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("B,S,H,KV,hd", [
    (1, 64, 4, 4, 32), (2, 128, 8, 2, 32), (1, 96, 4, 1, 64),
    (2, 64, 16, 16, 16),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 16), (False, 0)])
def test_flash_attention_sweep(B, S, H, KV, hd, causal, window):
    ks = keys(3, seed=S + H)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    got = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_attention_matches_chunked_model_path():
    from repro.models.attention import chunked_attention

    ks = keys(3, seed=21)
    B, S, H, KV, hd = 2, 128, 8, 4, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = chunked_attention(q, k, v, causal=True, window=0, chunk=32)
    np.testing.assert_allclose(
        got, np.asarray(want, np.float32), rtol=1e-4, atol=1e-5
    )


def test_flash_attention_bf16_inputs():
    ks = keys(3, seed=4)
    B, S, H, KV, hd = 1, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd)).astype(jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, KV, hd)).astype(jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, KV, hd)).astype(jnp.bfloat16)
    got = ops.flash_attention(q, k, v, block_q=32, block_k=32)
    want = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=3e-2, atol=3e-2)


# --------------------------------------------------------------------------- #
# Pad-and-slice tiling — odd/prime sizes must run on full-width tiles, not
# collapse to 1-row blocks (the old ``while size % bd: bd //= 2`` fallback).
# Every kernel pads the tiled axis to a block multiple and slices the
# padding back off; these tests force padding with small explicit blocks
# and check the padded run agrees with the oracle / an unpadded tiling.
# --------------------------------------------------------------------------- #


def test_choose_block_pads_instead_of_shrinking():
    from repro.kernels._tiling import choose_block

    # the ISSUE's acceptance shape: D=999 must keep 256-row tiles (padded
    # to 1024), not degrade to 1-row tiles
    assert choose_block(999, 256) == (256, 1024)
    assert choose_block(1024, 256) == (256, 1024)   # divisible: no padding
    assert choose_block(997, 128) == (128, 1024)    # prime size
    assert choose_block(5, 256) == (5, 5)           # size < block: one tile
    assert choose_block(48, 16) == (16, 48)


def test_pad_axis_identity_when_divisible():
    from repro.kernels._tiling import pad_axis

    x = jnp.arange(12.0).reshape(3, 4)
    assert pad_axis(x, 0, 3) is x
    y = pad_axis(x, 0, 5, value=-1.0)
    assert y.shape == (5, 4)
    np.testing.assert_array_equal(np.asarray(y[3:]), -1.0)
    np.testing.assert_array_equal(np.asarray(y[:3]), np.asarray(x))


@pytest.mark.parametrize("B,K,D", [(37, 5, 101), (13, 3, 7)])
def test_l1_topk2_odd_sizes_padded_tiles(B, K, D):
    k1, k2 = keys(2, seed=B)
    x = jax.random.normal(k1, (B, D))
    c = jax.random.normal(k2, (K, D))
    d1, d2, idx = ops.l1_topk2(x, c, block_b=16)   # Bp > B: rows padded
    rd1, rd2, ridx = ref.l1_topk2_ref(x, c)
    np.testing.assert_allclose(d1, rd1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(d2, rd2, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ridx))


def test_pairwise_l1_odd_sizes_padded_tiles():
    k1, k2 = keys(2, seed=41)
    a = jax.random.normal(k1, (37, 101))
    b = jax.random.normal(k2, (23, 101))
    got = ops.pairwise_l1(a, b, block_b1=16, block_b2=16, block_d=64)
    np.testing.assert_allclose(got, ref.pairwise_l1_ref(a, b),
                               rtol=1e-5, atol=1e-5)


def test_centroid_update_odd_feature_dim_padded_tiles():
    k1, k2, k3 = keys(3, seed=42)
    cents = jax.random.normal(k1, (5, 101))
    feats = jax.random.normal(k2, (17, 101))
    assign = jax.random.randint(k3, (17,), 0, 5)
    got = ops.centroid_update(cents, feats, assign, 4.0, block_d=64)
    np.testing.assert_allclose(
        got, ref.centroid_update_ref(cents, feats, assign, 4.0),
        rtol=1e-5, atol=1e-5)


def test_rglru_scan_odd_sizes_padded_tiles():
    ks = keys(3, seed=43)
    B, S, W = 3, 37, 53
    a = jax.random.uniform(ks[0], (B, S, W), minval=0.7, maxval=0.999)
    b = jax.random.normal(ks[1], (B, S, W)) * 0.1
    h0 = jax.random.normal(ks[2], (B, W))
    # every axis padded: batch 3->4, seq 37->48 (identity-recurrence pad
    # keeps h_last exact), width 53->64
    h, hl = ops.rglru_scan(a, b, h0, block_b=2, block_s=16, block_w=32)
    rh, rhl = ref.rglru_scan_ref(a, b, h0)
    np.testing.assert_allclose(h, rh, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(hl, rhl, rtol=1e-4, atol=1e-5)


def test_decode_gqa_odd_sizes_padded_tiles():
    ks = keys(4, seed=44)
    B, H, KV, hd, C = 5, 4, 2, 16, 37
    q = jax.random.normal(ks[0], (B, H, hd))
    kc = jax.random.normal(ks[1], (B, C, KV, hd))
    vc = jax.random.normal(ks[2], (B, C, KV, hd))
    pos = jax.random.randint(ks[3], (B,), 1, C + 1)
    slot = jnp.stack(
        [jnp.where(jnp.arange(C) < p, jnp.arange(C), -1) for p in pos]
    )
    got = ops.decode_gqa(q, kc, vc, slot, pos, block_b=4, block_c=16)
    want = ref.decode_gqa_ref(q, kc, vc, slot, pos)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_flash_attention_odd_seq_padded_tiles():
    ks = keys(3, seed=45)
    B, S, H, KV, hd = 2, 37, 4, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    got = ops.flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_fleet_priority_odd_device_count_padded_tiles():
    """Padded tiling (D=13 on 4-row blocks -> Dp=16) must be bit-identical
    to the single-tile run (bd=13, no padding) on the same inputs."""
    D, Q, n_tasks = 13, 4, 2
    ks = keys(12, seed=46)
    rng = np.random.default_rng(7)
    args = dict(
        policy=jnp.asarray(rng.integers(0, 4, D), jnp.int32),
        active=jnp.asarray(rng.integers(0, 2, (D, Q)), jnp.float32),
        laxity=jax.random.uniform(ks[0], (D, Q), minval=-1.0, maxval=3.0),
        release=jax.random.uniform(ks[1], (D, Q), maxval=2.0),
        utility=jax.random.uniform(ks[2], (D, Q)),
        mandatory=jnp.asarray(rng.integers(0, 2, (D, Q)), jnp.float32),
        alpha=jax.random.uniform(ks[3], (D,)),
        beta=jax.random.uniform(ks[4], (D,)),
        eta=jax.random.uniform(ks[5], (D,), minval=0.3, maxval=1.0),
        persistent=jnp.asarray(rng.integers(0, 2, D), jnp.float32),
        energy=jax.random.uniform(ks[6], (D,), maxval=0.05),
        e_opt=jax.random.uniform(ks[7], (D,), maxval=0.05),
        charge=jax.random.uniform(ks[8], (D,), maxval=0.01),
        capacity=jnp.full((D,), 0.1, jnp.float32),
        gate_e=jax.random.uniform(ks[9], (D, Q), maxval=0.02),
        drain=jax.random.uniform(ks[10], (D, Q), maxval=0.005),
        forced=jnp.asarray(rng.choice([-1, -1, -1, 0, 2], D), jnp.int32),
        task=jnp.asarray(rng.integers(0, n_tasks, (D, Q)), jnp.int32),
        rr_cursor=jnp.asarray(rng.integers(0, n_tasks, D), jnp.int32),
    )
    padded = ops.fleet_priority(*args.values(), n_tasks=n_tasks, block_d=4)
    single = ops.fleet_priority(*args.values(), n_tasks=n_tasks, block_d=32)
    for a, b, name in zip(padded, single, ("sel", "picked", "run", "e_new")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
