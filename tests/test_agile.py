"""Agile execution: early exit, unit budget, adaptation, utility thresholds
(paper §4) — on the session-trained CNN and a reduced transformer."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import kmeans as km
from repro.core import utility as util


def test_infer_early_exit_and_budget(agile_model, mnist_tiny):
    r = agile_model.infer(mnist_tiny.x_test[0], adapt=False)
    assert 0 <= r.prediction < mnist_tiny.n_classes
    assert r.units_executed <= agile_model.n_units
    if r.exit_unit >= 0:
        assert r.units_executed == r.exit_unit + 1
    # a unit budget of 1 must stop after one unit
    r1 = agile_model.infer(mnist_tiny.x_test[1], adapt=False, unit_budget=1)
    assert r1.units_executed == 1


def test_profile_batch_consistent_with_classifiers(agile_model, mnist_tiny):
    profiles = agile_model.profile_batch(
        mnist_tiny.x_test[:32], mnist_tiny.y_test[:32]
    )
    assert len(profiles) == 32
    for p in profiles:
        assert p.n_units == agile_model.n_units
        m = p.mandatory_units()
        assert 1 <= m <= p.n_units
        # margins are the scale-free cluster margins: within [0, 1]
        assert (p.margins >= 0).all() and (p.margins <= 1).all()


def test_early_exit_happens_on_separable_data(agile_model, mnist_tiny):
    profiles = agile_model.profile_batch(
        mnist_tiny.x_test[:48], mnist_tiny.y_test[:48]
    )
    mand = np.array([p.mandatory_units() for p in profiles])
    assert mand.mean() < agile_model.n_units  # paper: 5-26% time saving


def test_exit_accuracy_close_to_full(agile_model, mnist_tiny):
    """Paper Fig. 16: utility-based exit accuracy within a few points of
    full execution."""
    profiles = agile_model.profile_batch(
        mnist_tiny.x_test, mnist_tiny.y_test
    )
    full = np.mean([p.correct[p.n_units - 1] for p in profiles])
    exited = np.mean(
        [p.correct[p.mandatory_units() - 1] for p in profiles]
    )
    assert exited >= full - 0.15
    assert full > 1.5 / mnist_tiny.n_classes


def test_adaptation_updates_bank(agile_model, mnist_tiny):
    before = [np.asarray(uc.centroids).copy() for uc in agile_model.bank]
    moved = False
    for i in range(12):
        r = agile_model.infer(mnist_tiny.x_test[i], adapt=True)
        if r.adapted:
            moved = True
    assert moved
    deltas = [
        np.abs(np.asarray(uc.centroids) - b).max()
        for uc, b in zip(agile_model.bank, before)
    ]
    assert max(deltas) > 0.0


def test_calibrate_threshold_tradeoff(trained_cnn, mnist_tiny):
    """Paper Fig. 8: raising the threshold lowers the exit fraction and
    (weakly) raises exited-sample accuracy."""
    from repro.models.cnn import cnn_forward_all

    feats = [
        np.asarray(f) for f in cnn_forward_all(
            trained_cnn.cfg, trained_cnn.params,
            jnp.asarray(mnist_tiny.x_train),
        )
    ]
    uc = trained_cnn.bank[0]
    thr, curve = util.calibrate_threshold(
        uc, feats[0], mnist_tiny.y_train, min_accuracy=0.9
    )
    ts = [c[0] for c in curve]
    fracs = [c[1] for c in curve]
    assert ts == sorted(ts)
    assert all(b <= a + 1e-9 for a, b in zip(fracs, fracs[1:]))
    assert thr in ts


def test_entropy_utility():
    uniform = np.full((1, 4), 0.25)
    peaked = np.asarray([[0.97, 0.01, 0.01, 0.01]])
    assert util.entropy_utility(uniform)[0] == pytest.approx(2.0)
    assert util.entropy_utility(peaked)[0] < 0.3


def test_agile_transformer_units(key):
    """Transformer frontend: unit-wise execution with a fitted bank."""
    from repro.configs import get_config
    from repro.core.agile import AgileTransformer
    from repro.data import make_token_dataset
    from repro.models import transformer as T

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = T.init_params(cfg, key)
    toks, y = make_token_dataset(cfg.vocab, 32, 4, 64, separability=3.0)
    # fit a bank from the (untrained) per-unit pooled features
    feats = []
    x, enc = T.embed_inputs(cfg, params, {"tokens": jnp.asarray(toks)})
    for u in range(cfg.n_units):
        x, pooled = T.unit_forward(cfg, params, x, u, enc_out=enc)
        feats.append(np.asarray(pooled))
    bank = km.fit_bank(feats, y, n_sel=32)
    model = AgileTransformer(cfg, params, bank)
    assert model.n_units == cfg.n_units
    r = model.infer(toks[:1], adapt=False)
    assert 0 <= r.prediction < 4
    assert 1 <= r.units_executed <= model.n_units
