"""Mesh lowering / dry-run machinery.

Real mesh tests need >1 device, which requires setting XLA_FLAGS before jax
initialises — so they run in subprocesses with a small forced device count
(the full 512-device sweep is exercised by ``python -m repro.launch.dryrun``
and recorded in EXPERIMENTS.md).  Spec-inference tests run in-process.
"""
import json
import subprocess
import sys
import textwrap

import pytest

from _subproc import sub_env

SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax

from repro.configs import get_config
from repro.launch.lowering import analyze, lower_step
from repro.launch.mesh import make_mesh

mesh = make_mesh((2, 4), ("data", "model"))
cfg = get_config("{arch}").reduced()
res = lower_step(cfg, "{shape}", mesh)
rec = analyze(res)
print("RESULT" + json.dumps({{
    "flops": rec["hlo_flops_per_device"],
    "bytes": rec["hlo_bytes_per_device"],
    "ici": rec["collectives"]["ici_bytes"],
    "dominant": rec["roofline"]["dominant"],
    "mem": rec["memory"]["temp_size_in_bytes"],
}}))
"""


def run_sub(arch, shape):
    code = SUB.format(arch=arch, shape=shape)
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, env=sub_env(),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("qwen1.5-0.5b", "train_4k"),
    ("dbrx-132b", "train_4k"),          # MoE: expert sharding + all-to-all
    ("recurrentgemma-9b", "decode_32k"),  # hybrid decode state
    ("xlstm-125m", "long_500k"),        # native long-context decode
])
def test_lowering_compiles_on_8dev_mesh(arch, shape):
    rec = run_sub(arch, shape)
    assert rec["flops"] > 0
    assert rec["bytes"] > 0
    assert rec["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_long_500k_skip_is_honoured():
    code = SUB.format(arch="seamless-m4t-medium", shape="long_500k")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=900, env=sub_env(),
    )
    assert out.returncode != 0
    assert "ShapeSkip" in out.stderr or "skips long_500k" in out.stderr


# ----------------------------------------------------------------------- #
# Spec inference (no devices needed).
# ----------------------------------------------------------------------- #


def test_param_specs_divisible():
    """Every sharded dim must be divisible by its mesh axes (the contract
    sanitize_dim enforces) — checked over all architectures on an abstract
    16x16 mesh."""
    import jax
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.configs import ASSIGNED_ARCHS, get_config
    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import param_specs
    from repro.models import transformer as T

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        shapes = jax.eval_shape(
            lambda cfg=cfg: T.init_params(cfg, jax.random.key(0))
        )
        specs = param_specs(mesh, shapes)
        leaves = jax.tree.leaves(shapes)
        spec_leaves = jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, P)
        )
        assert len(leaves) == len(spec_leaves)
        n_sharded = 0
        for leaf, spec in zip(leaves, spec_leaves):
            for dim, axes in zip(leaf.shape, spec):
                if axes is None:
                    continue
                n_sharded += 1
                axes = (axes,) if isinstance(axes, str) else axes
                total = int(np.prod([sizes[a] for a in axes]))
                assert dim % total == 0, (arch, leaf.shape, spec)
        assert n_sharded > 0  # the model is actually distributed


def test_state_specs_shard_cache():
    import jax

    from repro.configs import get_config
    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import state_specs
    from repro.models import transformer as T

    mesh = make_abstract_mesh((16, 16), ("data", "model"))
    # glm4: kv=2 not divisible by 16 -> the cache LENGTH must shard
    cfg = get_config("glm4-9b")
    state = jax.eval_shape(lambda: T.init_decode_state(cfg, 128, 32768))
    specs = state_specs(mesh, state)
    k_spec = specs["stack"][0]["k"]
    assert "model" in str(k_spec)
    assert "data" in str(k_spec)
    # stablelm: kv=32 divisible -> heads shard, cache length replicated
    cfg2 = get_config("stablelm-3b")
    state2 = jax.eval_shape(lambda: T.init_decode_state(cfg2, 128, 32768))
    k2 = state2["stack"][0]["k"]
    spec2 = state_specs(mesh, state2)["stack"][0]["k"]
    # (n_scan, B, C, KV, hd): KV position carries the model axis
    assert spec2[3] == "model", spec2
    assert k2.shape[3] == 32


def test_batch_specs_batch_axis():
    import jax
    import jax.numpy as jnp

    from repro.launch.mesh import make_abstract_mesh
    from repro.launch.sharding import batch_specs

    mesh = make_abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
    spec = batch_specs(mesh, batch)["tokens"]
    assert spec[0] == ("pod", "data")
    # batch=1 (long_500k) falls back to replication
    one = {"tokens": jax.ShapeDtypeStruct((1, 8), jnp.int32)}
    assert batch_specs(mesh, one)["tokens"][0] is None
