"""Imprecise real-time scheduler (paper §5): priority functions, simulator
invariants, and the paper's qualitative claims on synthetic workloads."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import energy
from repro.core.scheduler import (
    CHRTClock,
    Job,
    JobProfile,
    SimConfig,
    TaskSpec,
    simulate,
    zeta,
    zeta_intermittent,
)

PERSISTENT = energy.Harvester("battery", 1.0, 0.0, 10.0)


def profile(n_units=4, exit_at=None, correct_from=0):
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    if exit_at is not None:
        passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    return JobProfile(margins, passes, correct)


def make_task(tid=0, n_jobs=20, period=1.0, deadline=2.0, unit_t=0.1,
              unit_e=1e-3, n_units=4, exit_at=1):
    return TaskSpec(
        task_id=tid,
        period=period,
        deadline=deadline,
        unit_time=np.full(n_units, unit_t),
        unit_energy=np.full(n_units, unit_e),
        profiles=[profile(n_units, exit_at) for _ in range(n_jobs)],
    )


# --------------------------------------------------------------------------- #
# Priority functions (Eqs. 6-7).
# --------------------------------------------------------------------------- #


def _job(deadline=2.0, utility=0.3, mandatory=True):
    p = profile(4, exit_at=None if mandatory else 0)
    j = Job(make_task(), 0, 0.0, deadline, p)
    if not mandatory:
        j.exited_at = 0
        j.last_pred_unit = 0
        j.unit = 1
    return j


def test_zeta_matches_eq6():
    j = _job(deadline=2.0, mandatory=True)
    alpha, beta = 0.5, 1.0
    got = zeta(j, t_now=1.0, alpha=alpha, beta=beta)
    want = (1 - 0.5 * (2.0 - 1.0)) + (1 - 1.0 * j.utility) + 1.0
    assert got == pytest.approx(want)


def test_zeta_orderings():
    """Tighter deadline, lower utility, mandatory status all raise priority."""
    t = 0.0
    tight = _job(deadline=1.0)
    loose = _job(deadline=3.0)
    assert zeta(tight, t, 0.25, 1.0) > zeta(loose, t, 0.25, 1.0)
    mand = _job(mandatory=True)
    opt = _job(mandatory=False)
    assert zeta(mand, t, 0.25, 1.0) > zeta(opt, t, 0.25, 1.0)


def test_zeta_intermittent_gates_optional():
    """Eq. 7: below the eta-weighted energy threshold, optional units get
    zero priority while mandatory units keep the base priority."""
    mand = _job(mandatory=True)
    opt = _job(mandatory=False)
    lo = zeta_intermittent(opt, 0.0, 0.25, 1.0, eta=0.3, e_curr=0.2,
                           e_opt=0.5)
    assert lo == 0.0
    hi = zeta_intermittent(opt, 0.0, 0.25, 1.0, eta=0.9, e_curr=0.9,
                           e_opt=0.5)
    assert hi > 0.0
    m = zeta_intermittent(mand, 0.0, 0.25, 1.0, eta=0.3, e_curr=0.2,
                          e_opt=0.5)
    assert m > 0.0


# --------------------------------------------------------------------------- #
# Simulator invariants.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("policy", ["edf", "edf-m", "rr", "zygarde"])
def test_persistent_underload_schedules_everything(policy):
    task = make_task(n_jobs=20, period=1.0, deadline=2.0, unit_t=0.05)
    res = simulate([task], PERSISTENT, eta=1.0,
                   sim=SimConfig(policy=policy, horizon=40.0))
    assert res.released == 20
    assert res.scheduled == 20
    assert res.deadline_misses == 0
    assert res.reboots == 0


@pytest.mark.parametrize("policy", ["edf", "edf-m", "zygarde"])
def test_scheduled_bounded_by_released(policy):
    task = make_task(n_jobs=30, period=0.5, deadline=1.0, unit_t=0.2)
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    res = simulate([task], harv, eta=0.7,
                   sim=SimConfig(policy=policy, horizon=30.0))
    assert 0 <= res.correct <= res.scheduled <= res.released
    assert res.scheduled + res.deadline_misses <= res.released + 1


def test_early_exit_reduces_units():
    """Early exit (EDF-M) executes fewer units than full EDF."""
    t_full = make_task(n_jobs=15, exit_at=None)  # never exits early
    t_exit = make_task(n_jobs=15, exit_at=0)     # exits after unit 1
    full = simulate([t_full], PERSISTENT, 1.0,
                    sim=SimConfig(policy="edf", horizon=30.0))
    part = simulate([t_exit], PERSISTENT, 1.0,
                    sim=SimConfig(policy="edf-m", horizon=30.0))
    assert part.units_executed < full.units_executed


def test_zygarde_runs_optional_units_when_energy_rich():
    task = make_task(n_jobs=10, period=2.0, deadline=4.0, unit_t=0.05,
                     exit_at=0)
    res = simulate([task], PERSISTENT, eta=1.0,
                   sim=SimConfig(policy="zygarde", horizon=30.0))
    assert res.optional_units > 0
    edfm = simulate([task], PERSISTENT, eta=1.0,
                    sim=SimConfig(policy="edf-m", horizon=30.0))
    assert edfm.optional_units == 0


def test_overload_zygarde_and_edfm_beat_edf():
    """Paper Figs. 17-20: with U > 1, imprecise policies schedule more jobs
    than EDF (which must run every unit)."""
    task = make_task(n_jobs=30, period=0.5, deadline=1.0, unit_t=0.2,
                     exit_at=0)  # mandatory = 1 unit of 4
    edf = simulate([task], PERSISTENT, 1.0,
                   sim=SimConfig(policy="edf", horizon=30.0))
    edfm = simulate([task], PERSISTENT, 1.0,
                    sim=SimConfig(policy="edf-m", horizon=30.0))
    zyg = simulate([task], PERSISTENT, 1.0,
                   sim=SimConfig(policy="zygarde", horizon=30.0))
    assert edfm.scheduled > edf.scheduled
    assert zyg.scheduled > edf.scheduled


def test_intermittent_power_causes_misses_and_reboots():
    task = make_task(n_jobs=20, period=1.0, deadline=2.0, unit_t=0.1,
                     unit_e=5e-2)
    weak = energy.Harvester("weak", 0.8, 0.8, 0.02)
    res = simulate([task], weak, eta=0.5,
                   sim=SimConfig(policy="zygarde", horizon=40.0, seed=3))
    assert res.idle_no_energy > 0
    assert res.scheduled < res.released


def test_queue_overflow_drops_jobs():
    task = make_task(n_jobs=40, period=0.05, deadline=0.2, unit_t=0.5)
    res = simulate([task], PERSISTENT, 1.0,
                   sim=SimConfig(policy="edf", horizon=10.0, queue_size=2))
    assert res.deadline_misses > 0


@given(st.integers(0, 10_000), st.sampled_from(["edf", "edf-m", "zygarde"]))
@settings(max_examples=12, deadline=None)
def test_simulator_accounting_property(seed, policy):
    """released == scheduled-or-missed under any seed/policy."""
    rng = np.random.default_rng(seed)
    task = make_task(
        n_jobs=int(rng.integers(5, 25)),
        period=float(rng.uniform(0.3, 2.0)),
        deadline=float(rng.uniform(0.5, 3.0)),
        unit_t=float(rng.uniform(0.02, 0.3)),
        exit_at=int(rng.integers(0, 4)),
    )
    harv = energy.Harvester("h", 0.9, 0.9, float(rng.uniform(0.01, 1.0)))
    res = simulate([task], harv, eta=0.6,
                   sim=SimConfig(policy=policy, horizon=20.0, seed=seed))
    assert res.scheduled + res.deadline_misses == res.released
    assert res.correct <= res.scheduled
    assert res.busy_time <= res.sim_time + 1e-6


def test_chrt_clock_error_distribution():
    clock = CHRTClock()
    rng = np.random.default_rng(0)
    errs = np.array([clock.read(100.0, rng) - 100.0 for _ in range(5000)])
    assert (errs == 0).mean() == pytest.approx(0.80, abs=0.03)
    assert (errs < 0).mean() < 0.04  # negative error < 3% (paper §8.7)


def test_chrt_slightly_degrades_schedule():
    task = make_task(n_jobs=25, period=1.0, deadline=2.0, unit_t=0.1)
    harv = energy.Harvester("h", 0.95, 0.95, 0.08)
    rtc = simulate([task], harv, 0.7,
                   sim=SimConfig(policy="zygarde", horizon=40.0, seed=1))
    chrt = simulate([task], harv, 0.7,
                    sim=SimConfig(policy="zygarde", horizon=40.0, seed=1,
                                  clock=CHRTClock()))
    # CHRT may cost a few jobs but not collapse (paper: < 0.1% loss at scale)
    assert chrt.scheduled >= rtc.scheduled - 3
