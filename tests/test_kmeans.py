"""Semi-supervised k-means classifier bank (paper §4.3)."""
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import kmeans as km


def blobs(n=120, d=20, k=4, sep=6.0, seed=0):
    rng = np.random.default_rng(seed)
    protos = rng.normal(size=(k, d)) * sep
    y = rng.integers(0, k, n)
    x = protos[y] + rng.normal(size=(n, d))
    return x.astype(np.float32), y.astype(np.int64)


def test_fit_and_classify_separable():
    x, y = blobs()
    uc = km.fit_unit_classifier(x, y, n_sel=20)
    pred, d1, d2, idx, margin = km.classify(uc, jnp.asarray(x))
    acc = (np.asarray(pred) == y).mean()
    assert acc > 0.95
    assert (np.asarray(d2) >= np.asarray(d1)).all()
    assert (np.asarray(margin) >= 0).all()


def test_select_k_best_finds_informative_dims():
    rng = np.random.default_rng(1)
    n = 400
    y = rng.integers(0, 2, n)
    x = rng.normal(size=(n, 10)).astype(np.float32)
    x[:, 3] += 5.0 * y  # only dim 3 carries signal
    idx = km.select_k_best(x, y, 1)
    assert list(idx) == [3]


def test_utility_test_threshold():
    x, y = blobs(sep=8.0)
    uc = km.fit_unit_classifier(x, y, n_sel=20, threshold=0.05)
    _, _, _, _, margin = km.classify(uc, jnp.asarray(x))
    passed = km.utility_test(uc, margin)
    assert float(jnp.mean(passed)) > 0.8  # well-separated data exits


def test_adapt_moves_centroid_toward_new_points():
    x, y = blobs(seed=2)
    uc = km.fit_unit_classifier(x, y, n_sel=20)
    shift = jnp.asarray(x[:8] + 10.0)  # distribution shift
    _, _, _, idx, _ = km.classify(uc, shift)
    new = km.adapt(uc, shift, idx, weight=4.0)
    moved = np.asarray(new.centroids) - np.asarray(uc.centroids)
    touched = np.unique(np.asarray(idx))
    assert np.abs(moved[touched]).max() > 0.1
    untouched = [j for j in range(uc.centroids.shape[0])
                 if j not in touched]
    if untouched:
        np.testing.assert_allclose(moved[untouched], 0.0, atol=1e-6)
    # counts grew only for touched clusters
    dc = np.asarray(new.counts) - np.asarray(uc.counts)
    assert dc.sum() == 8


@given(st.floats(1.0, 256.0))
@settings(max_examples=20, deadline=None)
def test_adapt_weight_bounds_motion(weight):
    """Weighted average: new centroid lies between old centroid and batch
    mean, closer to the old one for larger weight (paper §11.3)."""
    x, y = blobs(seed=3)
    uc = km.fit_unit_classifier(x, y, n_sel=20)
    pts = jnp.asarray(x[:6])
    idx = jnp.zeros((6,), jnp.int32)
    new = km.adapt(uc, pts, idx, weight=weight)
    old_c = np.asarray(uc.centroids[0])
    mean = np.asarray(pts.mean(0))
    got = np.asarray(new.centroids[0])
    lam = weight / (weight + 6.0)
    np.testing.assert_allclose(
        got, lam * old_c + (1 - lam) * mean, rtol=1e-4, atol=1e-4
    )


def test_propagate_matches_formula():
    """c^{i+1} = (1/r) sigma(W^{i+1} (r c^i)) for the touched clusters."""
    x, y = blobs(d=16, seed=4)
    uc0 = km.fit_unit_classifier(x, y, n_sel=16)
    rng = np.random.default_rng(5)
    W = jnp.asarray(rng.normal(size=(16, 16)).astype(np.float32))

    def unit_apply(f):
        return f @ W

    feats1 = np.maximum(x @ np.asarray(W), 0.0)
    uc1 = km.fit_unit_classifier(feats1, y, n_sel=16)
    touched = jnp.asarray([0, 2])
    out = km.propagate(uc0, uc1, unit_apply, touched)
    r = np.asarray(uc0.counts)[:, None]
    want = np.maximum((r * np.asarray(uc0.centroids)) @ np.asarray(W), 0) / r
    got = np.asarray(out.centroids)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(got[2], want[2], rtol=1e-4, atol=1e-4)
    # untouched clusters keep the target bank's centroids
    np.testing.assert_allclose(got[1], np.asarray(uc1.centroids)[1],
                               atol=1e-6)


def test_fit_bank_and_accuracy_monotone_layers(mnist_tiny, trained_cnn):
    """Deeper units should classify at least as well as the first unit on
    the training distribution (the layer-aware loss enforces this)."""
    from repro.models.cnn import cnn_forward_all

    feats = [
        np.asarray(f) for f in cnn_forward_all(
            trained_cnn.cfg, trained_cnn.params,
            jnp.asarray(mnist_tiny.x_train),
        )
    ]
    accs = km.bank_accuracy(trained_cnn.bank, feats, mnist_tiny.y_train)
    assert len(accs) == trained_cnn.cfg.n_units
    assert max(accs[1:]) >= accs[0] - 0.05
    assert accs[-1] > 1.5 / mnist_tiny.n_classes  # far above chance
