"""Shared helper for tests that spawn python subprocesses.

Subprocesses don't inherit pytest's ``pythonpath`` ini setting, so the
repo's ``src`` dir must be placed on PYTHONPATH explicitly for
``python -m repro...`` / ``python -c "import repro..."`` children to work
when the package is not pip-installed.
"""
from __future__ import annotations

import os
from pathlib import Path

_SRC = str(Path(__file__).resolve().parents[1] / "src")


def sub_env() -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env
