"""Streaming serve (`run_stream`) and fused serve-mode contracts.

The streaming path claims bit-exactness against the monolithic
:meth:`FleetServeEngine.run` for ANY chunking of the same job stream —
windowed feature staging, `job0` rebasing and donated log shifting must be
invisible — and the fused serve mode claims bit-exactness against the scan
path (the kernel body is the same `serve_step` trace).  These tests pin
both, plus the memory contract: chunk runners donate their carries
(`input_output_alias` in the compiled HLO) and the staged window tables are
O(chunk), not O(total jobs).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import energy
from repro.serve import FleetServeEngine, Request, ServeConfig


def _persistent():
    return energy.Harvester("battery", 1.0, 0.0, 1.0)


def _fresh_model(trained_cnn, threshold=None):
    from repro.core.agile import AgileCNN

    bank = [uc if threshold is None
            else uc._replace(threshold=jnp.float32(threshold))
            for uc in trained_cnn.bank]
    return AgileCNN(trained_cnn.cfg, trained_cnn.params, bank)


def _requests(ds, n, period):
    return [Request(ds.x_test[i], int(ds.y_test[i]), release=i * period)
            for i in range(n)]


def _cfg(policy, n, adapt, period=2.0, deadline=1.5):
    return ServeConfig(policy=policy, period=period, deadline=deadline,
                       horizon=n * period + 2.0, adapt=adapt,
                       start_charged=True, sim_dt=0.05)


def _engine(trained_cnn, cfg, threshold=None, **kw):
    return FleetServeEngine([_fresh_model(trained_cnn, threshold)],
                            _persistent(), eta=1.0, config=cfg,
                            feature_batch=1, **kw)


_LOG_FIELDS = ("units", "pred", "correct", "margin", "exit_unit", "sched")


def _assert_same_outcome(ra, rb, jobs=None):
    """Bitwise equality of per-job logs, end carry and fleet aggregates."""
    for f in _LOG_FIELDS:
        a, b = getattr(ra, f), getattr(rb, f)
        j = min(a.shape[-1], b.shape[-1]) if jobs is None else jobs
        np.testing.assert_array_equal(a[..., :j], b[..., :j], err_msg=f)
    for f, a, b in zip(ra.carry.dev._fields, ra.carry.dev, rb.carry.dev):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"dev.{f}")
    for f, a, b in zip(ra.carry.bank._fields, ra.carry.bank, rb.carry.bank):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"bank.{f}")
    assert ra.jobs == rb.jobs


@pytest.mark.parametrize("bank_mode", ["per-device", "shared"])
@pytest.mark.parametrize("n_chunks", [1, 3])
def test_stream_matches_monolithic(trained_cnn, mnist_tiny, bank_mode,
                                   n_chunks):
    """run_stream == run, bitwise, for any chunking — with adaptation on
    (the bank evolves across chunk boundaries) in both bank modes."""
    n = 6
    cfg = _cfg("zygarde", n, adapt=True)
    reqs = _requests(mnist_tiny, n, cfg.period)
    r_mono = _engine(trained_cnn, cfg, 0.02, bank_mode=bank_mode).run(
        [reqs], n_devices=2)
    r_st = _engine(trained_cnn, cfg, 0.02, bank_mode=bank_mode).run_stream(
        [reqs], n_devices=2, n_chunks=n_chunks)
    assert r_st.n_chunks == n_chunks
    _assert_same_outcome(r_mono, r_st, jobs=n)


def test_stream_per_device_streams(trained_cnn, mnist_tiny):
    """Per-device request streams (batched feature tables) stream the
    same way they run monolithically."""
    n = 5
    cfg = _cfg("zygarde", n, adapt=False)
    streams = [[_requests(mnist_tiny, n, cfg.period)],
               [_requests(mnist_tiny, n, cfg.period)[::-1]]]
    for s in streams:
        for k, r in enumerate(s[0]):
            s[0][k] = Request(r.x, r.label, release=k * cfg.period)
    r_mono = _engine(trained_cnn, cfg).run(streams)
    r_st = _engine(trained_cnn, cfg).run_stream(streams, n_chunks=2)
    _assert_same_outcome(r_mono, r_st, jobs=n)


def test_stream_total_jobs_cycles_base(trained_cnn, mnist_tiny):
    """total_jobs beyond the base stream cycles it: identical to a
    monolithic run over the explicitly repeated request list."""
    base_n, total = 3, 9
    cfg = _cfg("zygarde", total, adapt=False)
    base = _requests(mnist_tiny, base_n, cfg.period)
    repeated = [Request(base[i % base_n].x, base[i % base_n].label,
                        release=i * cfg.period) for i in range(total)]
    r_mono = _engine(trained_cnn, cfg).run([repeated], n_devices=2)
    r_st = _engine(trained_cnn, cfg).run_stream(
        [base], n_devices=2, total_jobs=total, n_chunks=3)
    assert r_st.jobs == r_mono.jobs == 2 * total
    _assert_same_outcome(r_mono, r_st, jobs=total)


def test_stream_donates_carry_and_bounds_memory(trained_cnn, mnist_tiny):
    """The chunk runners donate the ServeCarry (input/output aliasing in
    the compiled HLO) and the staged window tables are O(chunk): finer
    chunking shrinks the resident table, and both stay below the
    monolithic O(total-jobs) table footprint."""
    n = 40
    cfg = ServeConfig(policy="zygarde", period=2.0, deadline=1.5,
                      horizon=n * 2.0 + 2.0, adapt=False,
                      start_charged=True, sim_dt=0.05)
    reqs = _requests(mnist_tiny, n, 2.0)

    eng = _engine(trained_cnn, cfg)
    r8 = eng.run_stream([reqs], n_devices=2, n_chunks=8)
    assert eng._compiled, "chunk runners were not AOT-cached"
    for compiled in eng._compiled.values():
        assert "input_output_alias" in compiled.as_text()
    # no recompile across same-shape chunks: 8 chunks, at most 2 distinct
    # chunk lengths (array_split) -> at most 2 executables
    assert len(eng._compiled) <= 2

    r2 = _engine(trained_cnn, cfg).run_stream([reqs], n_devices=2,
                                              n_chunks=2)
    mono = _engine(trained_cnn, cfg)
    r_mono = mono.run([reqs], n_devices=2)
    _assert_same_outcome(r_mono, r8, jobs=n)

    # O(chunk) windows: the 8-chunk window is a strict subset of the job
    # axis, and no wider than the 2-chunk window
    w8 = r8.carry.log.units.shape[-1]
    w2 = r2.carry.log.units.shape[-1]
    assert w8 <= w2
    assert w8 < n
    assert 0 < r8.chunk_table_bytes <= r2.chunk_table_bytes
    if r8.peak_bytes and r2.peak_bytes:      # backend keeps memory stats
        assert r8.peak_bytes <= r2.peak_bytes * 1.25


def test_stream_telemetry_counters(trained_cnn, mnist_tiny):
    """The counters telemetry tier threads through the donated chunk
    runners and matches the monolithic run's counters."""
    from repro.telemetry import TelemetryConfig

    n = 5
    cfg = _cfg("zygarde", n, adapt=False)
    reqs = _requests(mnist_tiny, n, cfg.period)
    tcfg = TelemetryConfig()
    r_mono = _engine(trained_cnn, cfg).run([reqs], n_devices=2,
                                           telemetry=tcfg)
    r_st = _engine(trained_cnn, cfg).run_stream([reqs], n_devices=2,
                                                n_chunks=2, telemetry=tcfg)
    _assert_same_outcome(r_mono, r_st, jobs=n)
    for f, a, b in zip(r_mono.telemetry._fields, r_mono.telemetry,
                       r_st.telemetry):
        a, b = np.asarray(a), np.asarray(b)
        if np.issubdtype(a.dtype, np.floating):
            # float accumulators: chunked partial sums re-associate the
            # reduction -> ulp-level drift is expected, counts stay exact
            np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6,
                                       err_msg=f"telemetry.{f}")
        else:
            np.testing.assert_array_equal(a, b, err_msg=f"telemetry.{f}")


@pytest.mark.parametrize("bank_mode", ["per-device", "shared"])
@pytest.mark.parametrize("policy", ["zygarde", "edf"])
def test_fused_serve_matches_scan(trained_cnn, mnist_tiny, policy,
                                  bank_mode):
    """mode='fused' (classify in-tile, one pallas_call per segment) is
    bit-exact vs the scan path, with early exits exercised by a low
    uniform threshold."""
    n = 4
    cfg = _cfg(policy, n, adapt=False)
    reqs = _requests(mnist_tiny, n, cfg.period)
    r_scan = _engine(trained_cnn, cfg, 0.02, bank_mode=bank_mode).run(
        [reqs], n_devices=3)
    r_fused = _engine(trained_cnn, cfg, 0.02, bank_mode=bank_mode).run(
        [reqs], n_devices=3, mode="fused")
    _assert_same_outcome(r_scan, r_fused, jobs=n)


def test_fused_stream_matches_scan_stream(trained_cnn, mnist_tiny):
    """Streaming chunks through the fused kernel == streaming them
    through the scan == the monolithic run."""
    n = 5
    cfg = _cfg("zygarde", n, adapt=False)
    reqs = _requests(mnist_tiny, n, cfg.period)
    r_mono = _engine(trained_cnn, cfg).run([reqs], n_devices=2)
    r_fused = _engine(trained_cnn, cfg).run_stream(
        [reqs], n_devices=2, n_chunks=2, mode="fused")
    _assert_same_outcome(r_mono, r_fused, jobs=n)


def test_fused_rejects_adapt_and_telemetry(trained_cnn, mnist_tiny):
    from repro.telemetry import TelemetryConfig

    n = 2
    reqs = _requests(mnist_tiny, n, 2.0)
    with pytest.raises(ValueError, match="adapt"):
        _engine(trained_cnn, _cfg("zygarde", n, adapt=True)).run(
            [reqs], n_devices=1, mode="fused")
    with pytest.raises(ValueError, match="telemetry"):
        _engine(trained_cnn, _cfg("zygarde", n, adapt=False)).run(
            [reqs], n_devices=1, mode="fused",
            telemetry=TelemetryConfig())
    with pytest.raises(ValueError):
        _engine(trained_cnn, _cfg("zygarde", n, adapt=False)).run(
            [reqs], n_devices=1, mode="bogus")


def test_use_pallas_flag_deprecated():
    """Satellite: the legacy use_pallas= boolean warns and maps onto the
    mode strings; mode= itself stays silent."""
    import warnings

    from repro.fleet import simulator

    with pytest.warns(DeprecationWarning):
        assert simulator._resolve_mode(None, True) == "pallas"
    with pytest.warns(DeprecationWarning):
        assert simulator._resolve_mode(None, False) == "vmap"
    with pytest.warns(DeprecationWarning):
        # an explicit mode wins over the deprecated flag
        assert simulator._resolve_mode("fused", True) == "fused"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert simulator._resolve_mode(None, None) == "vmap"
        assert simulator._resolve_mode("pallas", None) == "pallas"
