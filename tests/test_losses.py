"""Loss functions (paper Eqs. 4-5) + baselines."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import losses


def test_contrastive_same_class_pulls():
    f = jnp.ones((4, 8))
    same = jnp.zeros((4,))
    assert float(losses.contrastive_loss(f, f, same)) == pytest.approx(0.0)
    # nonzero distance, same class -> positive pull term
    g = f + 0.5
    assert float(losses.contrastive_loss(f, g, same)) > 0.0


def test_contrastive_different_class_margin():
    f1 = jnp.zeros((4, 8))
    f2 = jnp.zeros((4, 8))  # distance 0, different class: max penalty
    diff = jnp.ones((4,))
    l0 = float(losses.contrastive_loss(f1, f2, diff, margin=1.0))
    assert l0 == pytest.approx(0.5)  # (1/2) * max(0, margin - 0)
    # far apart, different class: no penalty
    f2 = jnp.full((4, 8), 100.0)
    l1 = float(losses.contrastive_loss(f1, f2, diff, margin=1.0))
    assert l1 == pytest.approx(0.0)


def test_layer_aware_is_convex_combination():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 6)
    feats1 = [jax.random.normal(k, (8, 16)) for k in ks[:3]]
    feats2 = [jax.random.normal(k, (8, 16)) for k in ks[3:]]
    diff = jnp.asarray([0, 1] * 4, jnp.float32)
    per_layer = [
        float(losses.contrastive_loss(a, b, diff))
        for a, b in zip(feats1, feats2)
    ]
    la = float(losses.layer_aware_loss(feats1, feats2, diff))
    assert la == pytest.approx(np.mean(per_layer), rel=1e-5)
    # custom (unnormalised) coefficients are renormalised to sum to 1
    la2 = float(
        losses.layer_aware_loss(feats1, feats2, diff, coeffs=[2.0, 0.0, 0.0])
    )
    assert la2 == pytest.approx(per_layer[0], rel=1e-5)
    # final-layer baseline == last coefficient only
    fl = float(losses.final_layer_contrastive(feats1, feats2, diff))
    assert fl == pytest.approx(per_layer[-1], rel=1e-5)


def test_cross_entropy_matches_manual():
    logits = jnp.asarray([[2.0, 0.0, -1.0], [0.0, 3.0, 0.0]])
    labels = jnp.asarray([0, 1])
    got = float(losses.cross_entropy(logits, labels))
    p = np.exp(np.asarray(logits))
    p /= p.sum(-1, keepdims=True)
    want = -np.log(p[[0, 1], [0, 1]]).mean()
    assert got == pytest.approx(want, rel=1e-6)


def test_lm_loss_shifts():
    V = 8
    tokens = jnp.asarray([[1, 2, 3, 4]])
    # logits that put all mass on the correct next token
    logits = jnp.full((1, 4, V), -30.0)
    for t in range(3):
        logits = logits.at[0, t, int(tokens[0, t + 1])].set(30.0)
    assert float(losses.lm_loss(logits, tokens)) < 1e-3


def test_gradients_flow_through_layer_aware():
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (16, 16))

    def loss(w):
        f1 = jnp.tanh(jnp.ones((4, 16)) @ w)
        f2 = jnp.tanh(jnp.full((4, 16), 0.5) @ w)
        return losses.layer_aware_loss([f1], [f2], jnp.ones((4,)))

    g = jax.grad(loss)(w)
    assert float(jnp.abs(g).max()) > 0.0
    assert bool(jnp.isfinite(g).all())
