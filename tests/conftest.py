"""Shared fixtures.  Everything here runs on the single real CPU device —
the 512-device dry-run is exercised via subprocesses in test_dryrun.py."""
from __future__ import annotations

import numpy as np
import pytest

import jax


@pytest.fixture(scope="session")
def mnist_tiny():
    from repro.data import make_dataset

    return make_dataset("mnist", n_train=192, n_test=96, seed=0)


@pytest.fixture(scope="session")
def trained_cnn(mnist_tiny):
    """A small trained agile CNN + classifier bank (layer-aware loss)."""
    from repro.train import train_agile_cnn

    return train_agile_cnn(
        mnist_tiny, epochs=2, n_pairs=384, batch_size=32, seed=0
    )


@pytest.fixture(scope="session")
def agile_model(trained_cnn):
    from repro.core.agile import AgileCNN

    return AgileCNN(trained_cnn.cfg, trained_cnn.params, trained_cnn.bank)


@pytest.fixture(scope="session")
def online_adapt_demo():
    """The seeded nonstationary demo of ``examples/online_adapt.py``, run
    once per session (it sweeps a 10x10 static grid plus three adaptive
    trajectories) and shared by the online- and forecast-adaptation
    regression tests."""
    import importlib.util
    import pathlib

    path = (pathlib.Path(__file__).resolve().parent.parent / "examples"
            / "online_adapt.py")
    spec = importlib.util.spec_from_file_location("online_adapt_example",
                                                  path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod, mod.run_demo()


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


@pytest.fixture()
def key():
    return jax.random.PRNGKey(0)
