"""Vectorized fleet simulator: parity with the scalar event-driven
``simulate()``, sweep semantics, and the Pallas fleet_priority kernel.

Parity notes: the fleet path is fixed-timestep (dt = one fragment time by
default) while the scalar path is event-driven, so counts on energy-starved
boundary cases may differ by a few jobs; on deterministic persistent-power
workloads and on matched harvester event streams the counts agree exactly
or within the small tolerances asserted here.
"""
import dataclasses
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from _subproc import sub_env
from _workloads import MODES, make_task, profile
from repro import fleet
from repro.core import energy, policy
from repro.core.scheduler import (
    CHRTClock,
    Job,
    SimConfig,
    simulate,
    zeta,
    zeta_intermittent,
)

# workload builders (profile/make_task) and the calibrated parity bounds are
# shared with tests/test_parity.py via tests/_workloads.py
PERSISTENT = MODES["persistent"][0]


def fleet_device(task, harvester, eta, sim, **kw):
    cfg, statics = fleet.from_sim_config(task, harvester, eta, sim=sim, **kw)
    return fleet.simulate_fleet(cfg, statics).device(0)


# --------------------------------------------------------------------------- #
# Shared policy functions: the scalar priority API is a view over
# repro.core.policy (one source of truth for scalar + fleet + kernel).
# --------------------------------------------------------------------------- #


def test_scalar_priorities_delegate_to_policy_module():
    j = Job(make_task(), 0, 0.0, 2.0, profile(4))
    got = zeta(j, t_now=1.0, alpha=0.5, beta=1.0)
    want = policy.zeta_priority(2.0 - 1.0, j.utility, True, 0.5, 1.0)
    assert got == pytest.approx(float(want))
    got_i = zeta_intermittent(j, 1.0, 0.5, 1.0, eta=0.6, e_curr=0.2, e_opt=0.5)
    want_i = policy.zeta_intermittent_priority(
        1.0, j.utility, True, 0.5, 1.0, 0.6, 0.2, 0.5)
    assert got_i == pytest.approx(float(want_i))


# --------------------------------------------------------------------------- #
# Fleet vs scalar parity on matched single-device configs.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("pol", ["edf", "edf-m", "rr", "zygarde"])
def test_parity_persistent_underload_exact(pol):
    task = make_task(n_jobs=20, period=1.0, deadline=2.0, unit_t=0.05)
    sim = SimConfig(policy=pol, horizon=40.0)
    scalar = simulate([task], PERSISTENT, eta=1.0, sim=sim)
    d = fleet_device(task, PERSISTENT, 1.0, sim)
    assert d["released"] == scalar.released == 20
    assert d["scheduled"] == scalar.scheduled
    assert d["deadline_misses"] == scalar.deadline_misses == 0
    assert d["units_executed"] == scalar.units_executed
    assert d["reboots"] == scalar.reboots == 0


@pytest.mark.parametrize("pol", ["edf", "edf-m", "zygarde"])
def test_parity_persistent_overload(pol):
    """Overload (U > 1): imprecise-vs-full behaviour must carry over."""
    task = make_task(n_jobs=30, period=0.5, deadline=1.0, unit_t=0.2,
                     exit_at=0)
    sim = SimConfig(policy=pol, horizon=30.0)
    scalar = simulate([task], PERSISTENT, 1.0, sim=sim)
    d = fleet_device(task, PERSISTENT, 1.0, sim)
    assert d["released"] == scalar.released
    assert abs(d["scheduled"] - scalar.scheduled) <= 1
    assert abs(d["deadline_misses"] - scalar.deadline_misses) <= 1


@pytest.mark.parametrize("seed", [0, 3, 7])
def test_parity_intermittent_matched_events(seed):
    """With the harvester event stream matched bit-for-bit (same rng draw
    as the scalar path), intermittent counts line up too."""
    task = make_task(n_jobs=20, period=1.0, deadline=2.0, unit_t=0.1,
                     unit_e=5e-2)
    weak = energy.Harvester("weak", 0.8, 0.8, 0.02)
    sim = SimConfig(policy="zygarde", horizon=40.0, seed=seed)
    scalar = simulate([task], weak, 0.5, sim=sim)
    d = fleet_device(task, weak, 0.5, sim)
    assert d["scheduled"] == scalar.scheduled
    assert d["deadline_misses"] == scalar.deadline_misses
    assert abs(d["reboots"] - scalar.reboots) <= 1
    assert d["idle_no_energy"] > 0


@pytest.mark.parametrize("pol", ["zygarde", "edf-m", "edf"])
def test_parity_intermittent_mid_power(pol):
    """Energy-starved boundary regime: discretization may move a couple of
    jobs across the deadline, no more."""
    task = make_task(n_jobs=25, period=1.0, deadline=2.0, unit_t=0.1,
                     unit_e=8e-3)
    harv = energy.Harvester("h", 0.95, 0.95, 0.08)
    for seed in (1, 5):
        sim = SimConfig(policy=pol, horizon=40.0, seed=seed)
        scalar = simulate([task], harv, 0.7, sim=sim)
        d = fleet_device(task, harv, 0.7, sim)
        assert d["released"] == scalar.released
        assert abs(d["scheduled"] - scalar.scheduled) <= 3
        assert abs(d["deadline_misses"] - scalar.deadline_misses) <= 3


def test_fleet_accounting_invariant():
    """released == scheduled + missed for every device of a mixed sweep."""
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    res, meta = fleet.sweep(fleet.SweepGrid(
        task=make_task(n_jobs=25),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.3, 0.9),
        harvesters=(harv,),
        seeds=(0, 1),
        horizon=20.0,
    ))
    rel = np.asarray(res.released)
    assert (np.asarray(res.scheduled) + np.asarray(res.deadline_misses)
            == rel).all()
    assert (np.asarray(res.correct) <= np.asarray(res.scheduled)).all()
    assert (np.asarray(res.busy_time) <= np.asarray(res.sim_time) + 1e-5).all()
    assert len(meta) == rel.shape[0] == 16


def test_fleet_zygarde_beats_edf_under_overload():
    """Paper Figs. 17-20 carry over to the fleet path."""
    task = make_task(n_jobs=30, period=0.5, deadline=1.0, unit_t=0.2,
                     exit_at=0)
    res, meta = fleet.sweep(fleet.SweepGrid(
        task=task, policies=("edf", "edf-m", "zygarde"),
        harvesters=(PERSISTENT,), horizon=30.0,
    ))
    by_pol = {m["policy"]: int(res.scheduled[i]) for i, m in enumerate(meta)}
    assert by_pol["edf-m"] > by_pol["edf"]
    assert by_pol["zygarde"] > by_pol["edf"]


# --------------------------------------------------------------------------- #
# Sweep scale: >= 1000 device-configs in one jitted vmap call.
# --------------------------------------------------------------------------- #


def test_sweep_1000_devices_single_call():
    harv = energy.Harvester("h", 0.95, 0.95, 0.08)
    sun = energy.Harvester("sun", 0.9, 0.9, 0.05)
    grid = fleet.SweepGrid(
        task=make_task(n_jobs=15),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.2, 0.5, 0.8, 0.9, 1.0),
        harvesters=(harv, sun),
        capacitors=tuple(energy.Capacitor(capacitance_f=c)
                         for c in (0.01, 0.025, 0.05, 0.1, 0.2)),
        seeds=(0, 1, 2, 3, 4),
        horizon=10.0,
    )
    cfg, statics, meta = fleet.build(grid)
    assert cfg.n_devices == 4 * 5 * 2 * 5 * 5 == 1000
    res = fleet.simulate_fleet(cfg, statics)   # ONE jitted scan+vmap call
    assert res.released.shape == (1000,)
    assert len(meta) == 1000
    assert int(np.asarray(res.released).min()) == 10
    # eta/capacitor/policy variation actually changes outcomes
    assert len(np.unique(np.asarray(res.scheduled))) > 3


# --------------------------------------------------------------------------- #
# Fleet-path CHRT clock model: per-device drift rates.
# --------------------------------------------------------------------------- #


def test_zero_drift_is_exact_rtc():
    """clock_drift = 0 must leave the simulation bit-identical."""
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    grid = fleet.SweepGrid(task=make_task(n_jobs=20), etas=(0.5, 0.9),
                           harvesters=(harv,), seeds=(0, 1), horizon=20.0)
    base, _ = fleet.sweep(grid)
    drifted, meta = fleet.sweep(
        dataclasses.replace(grid, clock_drifts=(0.0,)))
    assert all(m["clock_drift"] == 0.0 for m in meta)
    for name in base._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(base, name)),
            np.asarray(getattr(drifted, name)), err_msg=name)


def test_fast_clock_drops_jobs_earlier():
    """A fast clock (positive drift) expires jobs before their true
    deadline: misses grow monotonically along the drift axis."""
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    drifts = (0.0, 0.05, 0.2)
    res, meta = fleet.sweep(fleet.SweepGrid(
        task=make_task(n_jobs=25, unit_e=8e-3),
        harvesters=(harv,), seeds=(0, 1, 2), clock_drifts=drifts,
        horizon=25.0,
    ))
    misses = np.asarray(res.deadline_misses, np.int64)
    by_drift = {d: int(misses[[i for i, m in enumerate(meta)
                               if m["clock_drift"] == d]].sum())
                for d in drifts}
    assert by_drift[0.0] <= by_drift[0.05] <= by_drift[0.2]
    assert by_drift[0.2] > by_drift[0.0]
    # accounting invariant survives drift
    assert (np.asarray(res.scheduled) + misses
            == np.asarray(res.released)).all()


def test_chrt_clock_maps_to_fleet_drift():
    """from_sim_config accepts a CHRTClock by converting it to the
    equivalent drift rate (instead of the old NotImplementedError)."""
    task = make_task(n_jobs=20)
    sim = SimConfig(policy="zygarde", horizon=40.0, clock=CHRTClock())
    cfg, _ = fleet.from_sim_config(task, PERSISTENT, 1.0, sim=sim)
    drift = float(np.asarray(cfg.clock_drift)[0])
    assert drift == pytest.approx(CHRTClock().equivalent_drift(40.0))
    assert drift > 0  # the CHRT reads fast on average (Table 5)


# --------------------------------------------------------------------------- #
# Sharded sweeps: device-axis partitioning must not change results.
# --------------------------------------------------------------------------- #

_SHARD_SUB = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import numpy as np
from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, TaskSpec
from repro.launch.mesh import make_fleet_mesh

n_units = 4
margins = np.linspace(0.05, 0.5, n_units)
passes = np.zeros(n_units, bool); passes[1:] = True
prof = JobProfile(margins, passes, np.ones(n_units, bool))
task = TaskSpec(task_id=0, period=1.0, deadline=2.0,
                unit_time=np.full(n_units, 0.1),
                unit_energy=np.full(n_units, 8e-3),
                profiles=[prof] * 15)
# 6 devices over a 4-way mesh: exercises the wrap-around padding too
grid = fleet.SweepGrid(task=task, policies=("zygarde", "edf"),
                       etas=(0.4, 0.9, 1.0),
                       harvesters=(energy.Harvester("h", 0.9, 0.9, 0.06),),
                       horizon=15.0)
res_u, meta = fleet.sweep(grid)
res_s, _ = fleet.sweep(grid, mesh=make_fleet_mesh())
for name in res_u._fields:
    np.testing.assert_array_equal(np.asarray(getattr(res_u, name)),
                                  np.asarray(getattr(res_s, name)),
                                  err_msg=name)

# segmented execution shards the carry pytree alongside the config
# (launch.sharding.shard_fleet_carry): still bit-identical, and the
# returned result/carry are sliced back to the 6 real devices
cfg_b, statics_b, _ = fleet.build(grid)
res_g, carry_g = fleet.run_segments(cfg_b, statics_b, 5,
                                    mesh=make_fleet_mesh())
for name in res_u._fields:
    np.testing.assert_array_equal(np.asarray(getattr(res_u, name)),
                                  np.asarray(getattr(res_g, name)),
                                  err_msg="segmented " + name)
import jax
assert all(leaf.shape[0] == 6 for leaf in jax.tree.leaves(carry_g))

# the adapt objective shards its candidate population the same way
import dataclasses
from repro import adapt
prob = adapt.TuneProblem(task=task, harvesters=grid.harvesters,
                         seeds=(0, 1), horizon=15.0)
x = {"eta": np.linspace(0.1, 1.0, 5, dtype=np.float32),
     "e_opt_fraction": np.linspace(0.1, 0.9, 5, dtype=np.float32)}
plain = prob.objective()(x)
sharded = dataclasses.replace(prob, mesh=make_fleet_mesh()).objective()(x)
# per-device counts are bit-identical (asserted above); the per-candidate
# score reduction crosses shards, so its summation order may differ by ulps
np.testing.assert_allclose(np.asarray(plain), np.asarray(sharded),
                           rtol=1e-6, atol=0)
print("SHARD_OK", len(meta))
"""


def test_sharded_sweep_matches_unsharded_4dev():
    """fleet.sweep over a real 4-device mesh (forced host devices, so a
    subprocess) is bit-identical to the single-device call."""
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_SHARD_SUB)],
        capture_output=True, text=True, timeout=600, env=sub_env(),
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "SHARD_OK 6" in out.stdout


def test_sharded_sweep_trivial_mesh_inprocess():
    """mesh over the in-process device count (1 on CPU) is also exact."""
    from repro.launch.mesh import make_fleet_mesh

    harv = energy.Harvester("h", 0.9, 0.9, 0.06)
    grid = fleet.SweepGrid(task=make_task(n_jobs=15), etas=(0.4, 1.0),
                           harvesters=(harv,), horizon=15.0)
    res_u, _ = fleet.sweep(grid)
    res_s, _ = fleet.sweep(grid, mesh=make_fleet_mesh())
    for name in res_u._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res_u, name)),
            np.asarray(getattr(res_s, name)), err_msg=name)
    # run_segments on the same mesh shards the carry like the config
    cfg, statics, _ = fleet.build(grid)
    res_g, carry = fleet.run_segments(cfg, statics, 3, mesh=make_fleet_mesh())
    for name in res_u._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res_u, name)),
            np.asarray(getattr(res_g, name)), err_msg="segmented " + name)
    import jax
    assert all(leaf.shape[0] == cfg.n_devices
               for leaf in jax.tree.leaves(carry))


# --------------------------------------------------------------------------- #
# Pallas fleet_priority kernel: bit-identical to the pure-jnp pick.
# --------------------------------------------------------------------------- #


def test_pallas_priority_kernel_matches_jnp_path():
    harv = energy.Harvester("h", 0.9, 0.9, 0.06)
    grid = fleet.SweepGrid(
        task=make_task(n_jobs=15, unit_e=8e-3),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.4, 1.0),
        harvesters=(harv,),
        seeds=(0, 2),
        horizon=15.0,
    )
    cfg, statics, _ = fleet.build(grid)
    ref = fleet.simulate_fleet(cfg, statics, mode="vmap")
    ker = fleet.simulate_fleet(cfg, statics, mode="pallas")
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(ker, name)),
            err_msg=name)
