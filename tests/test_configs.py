"""Config registry: assigned architectures, exact dims, reduced() contract."""
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    INPUT_SHAPES,
    get_config,
    list_configs,
)

# (name, family, layers, d_model, heads, kv, d_ff, vocab, experts, top_k)
ASSIGNED_DIMS = {
    "dbrx-132b": ("moe", 40, 6144, 48, 8, 10752, 100352, 16, 4),
    "minitron-8b": ("dense", 32, 4096, 32, 8, 16384, 256000, 0, 0),
    "qwen3-moe-235b-a22b": ("moe", 94, 4096, 64, 4, 1536, 151936, 128, 8),
    "recurrentgemma-9b": ("hybrid", 38, 4096, 16, 1, 12288, 256000, 0, 0),
    "internvl2-2b": ("vlm", 24, 2048, 16, 8, 8192, 92553, 0, 0),
    "stablelm-3b": ("dense", 32, 2560, 32, 32, 6912, 50304, 0, 0),
    "xlstm-125m": ("ssm", 12, 768, 4, 4, 0, 50304, 0, 0),
    "glm4-9b": ("dense", 40, 4096, 32, 2, 13696, 151552, 0, 0),
    "qwen1.5-0.5b": ("dense", 24, 1024, 16, 16, 2816, 151936, 0, 0),
    "seamless-m4t-medium": ("audio", 12, 1024, 16, 16, 4096, 256206, 0, 0),
}


def test_all_assigned_registered():
    names = list_configs()
    for arch in ASSIGNED_ARCHS:
        assert arch in names
    assert len(ASSIGNED_ARCHS) == 10


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_assigned_dims_exact(arch):
    cfg = get_config(arch)
    fam, L, d, H, KV, ff, V, E, K = ASSIGNED_DIMS[arch]
    assert cfg.family == fam
    assert cfg.n_layers == L
    assert cfg.d_model == d
    assert cfg.n_heads == H
    assert cfg.n_kv_heads == KV
    assert cfg.d_ff == ff
    assert cfg.vocab == V
    assert cfg.n_experts == E
    assert cfg.top_k == K
    assert cfg.source  # every config cites its source


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_reduced_contract(arch):
    """Assignment: reduced variant has <= 4 layers (one pattern period),
    d_model <= 512, <= 4 experts."""
    r = get_config(arch).reduced()
    assert r.n_layers <= 4
    assert r.d_model <= 512
    assert r.n_experts <= 4
    assert r.vocab <= 512
    assert r.family == get_config(arch).family
    assert r.pattern_period == get_config(arch).pattern_period
    assert r.n_heads % r.n_kv_heads == 0
    assert r.padded_vocab == r.vocab  # pad disabled for smoke shapes


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_padded_vocab_mesh_divisible(arch):
    cfg = get_config(arch)
    assert cfg.padded_vocab % 128 == 0
    assert cfg.padded_vocab % 16 == 0  # 16-way model mesh axis
    assert cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab - cfg.vocab < cfg.vocab_pad


def test_input_shapes_assigned():
    assert INPUT_SHAPES["train_4k"].seq_len == 4096
    assert INPUT_SHAPES["train_4k"].global_batch == 256
    assert INPUT_SHAPES["prefill_32k"].seq_len == 32768
    assert INPUT_SHAPES["prefill_32k"].global_batch == 32
    assert INPUT_SHAPES["decode_32k"].seq_len == 32768
    assert INPUT_SHAPES["decode_32k"].global_batch == 128
    assert INPUT_SHAPES["long_500k"].seq_len == 524288
    assert INPUT_SHAPES["long_500k"].global_batch == 1


def test_param_counts_match_source_scale():
    """Analytic parameter counts land near the headline sizes."""
    assert 120e9 < get_config("dbrx-132b").param_count() < 145e9
    assert 200e9 < get_config("qwen3-moe-235b-a22b").param_count() < 260e9
    # active params for MoE well below total
    dbrx = get_config("dbrx-132b")
    assert dbrx.active_param_count() < 0.4 * dbrx.param_count()
    assert 6e9 < get_config("minitron-8b").param_count() < 10e9
    assert 8e9 < get_config("glm4-9b").param_count() < 11e9
    assert 0.1e9 < get_config("xlstm-125m").param_count() < 0.25e9
    assert 0.4e9 < get_config("qwen1.5-0.5b").param_count() < 0.8e9


def test_long_context_policy():
    assert get_config("recurrentgemma-9b").long_context == "native"
    assert get_config("xlstm-125m").long_context == "native"
    assert get_config("seamless-m4t-medium").long_context == "skip"
    for arch in ("dbrx-132b", "glm4-9b", "minitron-8b", "qwen1.5-0.5b",
                 "stablelm-3b", "internvl2-2b", "qwen3-moe-235b-a22b"):
        cfg = get_config(arch)
        assert cfg.long_context == "window"
        assert cfg.long_window > 0


def test_unknown_arch_raises():
    with pytest.raises(KeyError):
        get_config("gpt-5")
