"""In-trajectory online adaptation (`repro.adapt.online`).

Three layers:

* hypothesis property tests for the estimators — the EWMA (and quantile)
  eta estimate must never leave the envelope of the measurements it has
  seen, and must converge geometrically on a stationary stream;
* integration: the full :class:`OnlineAdapter` hook on a *stationary*
  harvester trace keeps its estimate inside the observed per-segment
  measurement envelope and lands near the offline Eq. 3 measurement;
* the seeded nonstationary regression: on the solar -> RF -> occluded
  trace of ``examples/online_adapt.py``, mid-trajectory re-estimation must
  beat the best static tuned (eta, E_opt) constants — the paper's claim
  that runtime adaptation dominates shipped constants.
"""
from __future__ import annotations

import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from repro import adapt, fleet
from repro.core import energy


# --------------------------------------------------------------------------- #
# Estimator properties.
# --------------------------------------------------------------------------- #


@settings(max_examples=60, deadline=None)
@given(
    st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
             max_size=30),
    st.floats(min_value=0.01, max_value=1.0),
)
def test_ewma_stays_within_observed_envelope(measurements, rho):
    est = adapt.EwmaEstimator(rho)
    seen = []
    for m in measurements:
        seen.append(m)
        e = float(est.update(np.asarray([m]))[0])
        assert min(seen) - 1e-12 <= e <= max(seen) + 1e-12


@settings(max_examples=40, deadline=None)
@given(
    st.floats(min_value=0.0, max_value=1.0),
    st.floats(min_value=0.0, max_value=1.0),
    st.floats(min_value=0.05, max_value=1.0),
    st.integers(min_value=1, max_value=50),
)
def test_ewma_converges_geometrically_on_stationary_stream(e0, m, rho, n):
    """|est - m| after n constant measurements is bounded by the geometric
    contraction (1 - rho)^n of the initial error."""
    est = adapt.EwmaEstimator(rho)
    est.update(np.asarray([e0]))
    for _ in range(n):
        est.update(np.asarray([m]))
    err = abs(float(est.estimate[0]) - m)
    assert err <= (1.0 - rho) ** n * abs(e0 - m) + 1e-9


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=1,
             max_size=30),
    st.floats(min_value=0.0, max_value=1.0),
    st.integers(min_value=1, max_value=10),
)
def test_quantile_estimator_stays_within_envelope(measurements, q, window):
    est = adapt.QuantileEstimator(q, window)
    seen = []
    for m in measurements:
        seen.append(m)
        e = float(est.update(np.asarray([m]))[0])
        assert min(seen) - 1e-12 <= e <= max(seen) + 1e-12


def test_estimator_registry_and_validation():
    assert set(adapt.ESTIMATORS) == {"ewma", "quantile"}
    with pytest.raises(ValueError, match="rho"):
        adapt.EwmaEstimator(0.0)
    with pytest.raises(ValueError, match="q must"):
        adapt.QuantileEstimator(q=1.5)
    with pytest.raises(ValueError, match="estimator"):
        adapt.OnlineAdapter(fleet.FleetStatics(), estimator="nope")


# --------------------------------------------------------------------------- #
# Observed statistics.
# --------------------------------------------------------------------------- #


def test_observed_eta_matches_offline_measurement():
    """On a window fully inside the observed prefix, observed_eta is exactly
    eta_factor of that (binarized) window."""
    rng = np.random.default_rng(0)
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    ev = harv.sample_events(rng, 100, init=1).astype(np.float32)[None, :]
    got = adapt.observed_eta(ev, t_end=60.0, slot_s=1.0, window_s=25.0,
                             n_max=5)
    want = energy.eta_factor(ev[0, 35:60].astype(np.int8), n_max=5)
    assert got.shape == (1,)
    assert got[0] == pytest.approx(want)
    # before anything is observed: patternless prior
    assert adapt.observed_eta(ev, 0.0, 1.0, 25.0)[0] == 0.0


def test_observed_supply_is_windowed_mean_power():
    ev = np.zeros((2, 50), np.float32)
    ev[0, 20:30] = 1.0
    ev[1, :] = 0.5                       # fractional amplitudes count pro rata
    got = adapt.observed_supply(ev, np.asarray([0.1, 0.2]), t_end=30.0,
                                slot_s=1.0, window_s=10.0)
    np.testing.assert_allclose(got, [0.1, 0.5 * 0.2])


def test_workload_demand_mandatory_below_full(online_adapt_demo):
    ex, _ = online_adapt_demo
    cfg, _ = ex.build_fleet([(0.5, 0.5)], ex.nonstationary_trace(0))
    mand, full = adapt.workload_demand(cfg)
    # mandatory = 2 of 5 units per 1 s period, full = all 5
    assert mand[0] == pytest.approx(2 * 8e-3, rel=1e-6)
    assert full[0] == pytest.approx(5 * 8e-3, rel=1e-6)


# --------------------------------------------------------------------------- #
# Integration: stationary trace convergence.
# --------------------------------------------------------------------------- #


def test_online_eta_converges_on_stationary_trace():
    """On a stationary bursty harvester the adapter's estimate stays inside
    the envelope of its per-segment measurements and ends near the offline
    whole-trace Eq. 3 value."""
    from repro.core.scheduler import JobProfile, TaskSpec
    from repro.fleet import grid as fgrid

    horizon = 120.0
    harv = energy.Harvester("h", 0.9, 0.9, 0.05)
    events = fgrid.sample_events(harv, horizon, seed=4)
    n_units = 4
    prof = JobProfile(np.linspace(0.1, 0.5, n_units),
                      np.array([False, True, True, True]),
                      np.ones(n_units, bool))
    task = TaskSpec(task_id=0, period=1.0, deadline=2.0,
                    unit_time=np.full(n_units, 0.1),
                    unit_energy=np.full(n_units, 5e-3),
                    profiles=[prof] * (int(horizon) + 2))
    dev = fgrid.device_config(task, harv, 0.5, energy.Capacitor(),
                              policy="zygarde", horizon=horizon,
                              events=events)
    cfg = fgrid.stack_configs([dev])
    statics = fleet.FleetStatics(dt=0.025, horizon=horizon, slot_s=1.0)
    adapter = adapt.OnlineAdapter(statics, cfg, rho=0.4, window_s=40.0,
                                  n_max=5, adapt_e_opt=False)
    fleet.run_segments(cfg, statics, 12, hook=adapter.hook)

    measured = np.array([h["measured"][0] for h in adapter.history])
    eta_hat = np.array([h["eta_hat"][0] for h in adapter.history])
    for i in range(len(measured)):
        lo, hi = measured[: i + 1].min(), measured[: i + 1].max()
        assert lo - 1e-9 <= eta_hat[i] <= hi + 1e-9
    offline = energy.eta_factor(events.astype(np.int8), n_max=5)
    # stationary source: the tracked estimate lands near the offline value
    assert abs(eta_hat[-1] - offline) < 0.25
    assert eta_hat[-1] > 0.3           # clearly not the patternless prior


# --------------------------------------------------------------------------- #
# The nonstationary regression: online beats the best static constants.
# --------------------------------------------------------------------------- #


def test_online_beats_best_static_on_nonstationary_trace(online_adapt_demo):
    """Pins the example's seeded win: on the solar -> RF -> occluded trace,
    mid-trajectory re-estimation beats the best of 100 statically tuned
    (eta, E_opt) points, which itself beats nothing-to-sneeze-at paper
    defaults.  Fully deterministic (seeded trace, fixed grids)."""
    _, out = online_adapt_demo
    assert out["online"]["score"] > out["best_static"]["score"] + 0.01
    assert out["best_static"]["score"] >= out["default"]["score"]
    # the adaptation actually moved: eta estimates span the regimes
    eta_hat = np.array([h["eta_hat"][0] for h in out["history"]])
    assert eta_hat.max() > 0.9 and eta_hat.min() < 0.3
    fracs = np.array([h["e_opt_frac"][0] for h in out["history"]])
    assert fracs.max() > 0.9 and fracs.min() < 0.1
