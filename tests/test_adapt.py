"""Online policy-search subsystem (repro.adapt): search-space plumbing,
driver convergence on a known landscape, parameter threading into
FleetConfig arrays, and the acceptance property — the ES driver finds
scheduler parameters whose fleet-simulated on-time accuracy beats the
paper-default constants on a seeded multi-harvester grid.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro import adapt
from repro.core import energy
from repro.core.scheduler import CHRTClock, JobProfile, TaskSpec
from repro.core.utility import scalarized_objective


def make_task(n_jobs=30, n_units=4, exit_at=1, correct_from=2, task_id=0,
              period=1.0, deadline=2.0):
    """Workload with accuracy headroom: the utility test passes after unit
    `exit_at` but predictions only become correct from unit `correct_from`,
    so optional execution (deeper units) buys accuracy when energy allows."""
    margins = np.linspace(0.05, 0.5, n_units)
    passes = np.zeros(n_units, bool)
    passes[exit_at:] = True
    correct = np.zeros(n_units, bool)
    correct[correct_from:] = True
    prof = JobProfile(margins, passes, correct)
    return TaskSpec(
        task_id=task_id, period=period, deadline=deadline,
        unit_time=np.full(n_units, 0.1),
        unit_energy=np.full(n_units, 8e-3),
        profiles=[prof] * n_jobs,
    )


HARVESTERS = (energy.Harvester("solar", 0.95, 0.95, 0.08),
              energy.Harvester("rf", 0.85, 0.85, 0.05),
              energy.Harvester("piezo", 0.90, 0.90, 0.06))


@pytest.fixture(scope="module")
def problem():
    return adapt.TuneProblem(task=make_task(), harvesters=HARVESTERS,
                             seeds=(0, 1), horizon=30.0)


# --------------------------------------------------------------------------- #
# SearchSpace.
# --------------------------------------------------------------------------- #


def test_space_sample_within_bounds():
    space = adapt.SearchSpace.of(eta=(0.1, 0.9), e_opt_fraction=(0.2, 0.8))
    x = space.sample(np.random.default_rng(0), 100)
    assert x.shape == (100, 2)
    assert (x >= space.lows).all() and (x <= space.highs).all()
    d = space.to_dict(x)
    assert set(d) == {"eta", "e_opt_fraction"}
    np.testing.assert_array_equal(d["eta"], x[:, 0])


def test_space_grid_fits_budget():
    space = adapt.SearchSpace.of(a=(0.0, 1.0), b=(0.0, 1.0))
    lattice = space.grid(60)    # floor(sqrt(60)) = 7 per dim
    assert lattice.shape == (49, 2)
    assert len(np.unique(lattice[:, 0])) == 7


def test_integer_knobs_snap_and_tune_returns_ints():
    """`(lo, hi, int)` bounds mark integer knobs (e.g. the forecast
    controller's cluster count): samples, clipped ES offspring and the grid
    lattice all land on whole numbers, and tune() reports python ints."""
    space = adapt.SearchSpace.of(n_clusters=(2, 6, int), rho=(0.1, 0.9))
    x = space.sample(np.random.default_rng(0), 64)
    assert np.all(x[:, 0] == np.round(x[:, 0]))
    assert np.all((x[:, 0] >= 2) & (x[:, 0] <= 6))
    assert not np.all(x[:, 1] == np.round(x[:, 1]))
    clipped = space.clip(np.array([[3.4, 0.5], [9.0, 0.5]]))
    np.testing.assert_array_equal(clipped[:, 0], [3.0, 6.0])
    lattice = space.grid(60)
    assert set(np.unique(lattice[:, 0])) <= {2.0, 3.0, 4.0, 5.0, 6.0}
    # fractional bounds: snapping must stay inside them (5.4 in (2, 5.5)
    # must not round out to 6) and the grid lattice likewise
    frac_space = adapt.SearchSpace.of(n=(2.0, 5.5, int))
    np.testing.assert_array_equal(
        frac_space.clip(np.array([[5.4], [1.2]]))[:, 0], [5.0, 2.0])
    assert frac_space.grid(10)[:, 0].max() <= 5.0
    with pytest.raises(ValueError, match="no integer"):
        adapt.SearchSpace.of(n=(2.1, 2.9, int))

    def objective(params):
        # optimum at n_clusters=4, rho=0.5
        return -(np.asarray(params["n_clusters"]) - 4) ** 2 \
            - (np.asarray(params["rho"]) - 0.5) ** 2

    res = adapt.tune(objective, space, budget=96, driver="es", seed=0)
    assert isinstance(res.best_params["n_clusters"], int)
    assert res.best_params["n_clusters"] == 4
    assert isinstance(res.best_params["rho"], float)


# --------------------------------------------------------------------------- #
# Drivers on a known landscape: every driver must localise the optimum of a
# smooth unimodal function with a modest budget.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("driver", sorted(adapt.DRIVERS))
def test_driver_convergence_quadratic(driver):
    target = np.array([0.3, 0.7])

    def objective(params):
        x = np.stack([params["a"], params["b"]], axis=1)
        return -((x - target) ** 2).sum(axis=1)

    space = adapt.SearchSpace.of(a=(0.0, 1.0), b=(0.0, 1.0))
    res = adapt.tune(objective, space, budget=256, driver=driver, seed=0)
    assert res.n_evals <= 256
    best = np.array([res.best_params["a"], res.best_params["b"]])
    assert np.abs(best - target).max() < 0.1, res
    # history tracks a monotone best
    bests = [h["best_score"] for h in res.history]
    assert bests == sorted(bests)


def test_es_improves_on_initial_population():
    """The ES generations must actually move past the seed block."""
    target = np.array([0.42, 0.13, 0.87])

    def objective(params):
        x = np.stack([params[k] for k in ("a", "b", "c")], axis=1)
        return -((x - target) ** 2).sum(axis=1)

    space = adapt.SearchSpace.of(a=(0, 1), b=(0, 1), c=(0, 1))
    res = adapt.tune(objective, space, budget=200, driver="es", seed=3,
                     pop_size=20)
    first_block = res.history[0]["best_score"]
    assert res.best_score > first_block


def test_cma_converges_on_correlated_quadratic():
    """Full-covariance CMA-ES must localise the optimum of a *rotated*
    anisotropic quadratic tightly — the landscape whose knob coupling the
    isotropic ES cannot represent — and keep covariance/step-size state
    finite throughout."""
    A = np.array([[4.0, 1.8], [1.8, 1.0]])   # correlated curvature
    target = np.array([0.3, 0.7])

    def objective(params):
        x = np.stack([params["a"], params["b"]], axis=1) - target
        return -np.einsum("ni,ij,nj->n", x, A, x)

    space = adapt.SearchSpace.of(a=(0.0, 1.0), b=(0.0, 1.0))
    res = adapt.tune(objective, space, budget=256, driver="cma", seed=0)
    assert res.n_evals <= 256
    best = np.array([res.best_params["a"], res.best_params["b"]])
    assert np.abs(best - target).max() < 0.02, res
    assert np.isfinite(res.best_score)
    bests = [h["best_score"] for h in res.history]
    assert bests == sorted(bests)


def test_cma_tuned_beats_paper_default(problem):
    """Fleet-objective smoke: the CMA driver drives the same batched fleet
    simulation as the other drivers and beats the paper-default constants
    on the seeded multi-harvester grid."""
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = problem.score(problem.default_params())
    res = adapt.tune(problem.objective(), space, budget=96, driver="cma",
                     seed=0)
    assert res.best_score > default_score, (res, default_score)
    assert problem.score(res.best_params) == pytest.approx(res.best_score)


def test_tune_rejects_unknown_driver():
    space = adapt.SearchSpace.of(a=(0, 1))
    with pytest.raises(KeyError):
        adapt.tune(lambda p: p["a"], space, 8, driver="anneal")


def test_grid_driver_respects_tiny_budget():
    space = adapt.SearchSpace.of(a=(0, 1), b=(0, 1))
    res = adapt.tune(lambda p: -p["a"], space, budget=3, driver="grid")
    assert res.n_evals <= 3


# --------------------------------------------------------------------------- #
# Parameter threading: candidate values land in the FleetConfig arrays.
# --------------------------------------------------------------------------- #


def test_apply_params_threads_arrays(problem):
    base, _ = problem._base
    d = base.n_devices
    eta = jnp.linspace(0.1, 0.9, d)
    frac = jnp.full((d,), 0.25)
    thr = jnp.full((d,), 0.3)
    cfg = adapt.apply_params(
        base, {"eta": eta, "e_opt_fraction": frac, "exit_threshold": thr})
    np.testing.assert_allclose(np.asarray(cfg.eta), np.asarray(eta))
    np.testing.assert_allclose(np.asarray(cfg.e_opt),
                               0.25 * np.asarray(base.capacity), rtol=1e-6)
    assert np.asarray(cfg.use_exit_thr).all()
    assert np.asarray(cfg.exit_thr).shape == np.asarray(base.exit_thr).shape
    np.testing.assert_allclose(np.asarray(cfg.exit_thr), 0.3)
    # per-unit override targets one (all-tasks) column of the (D, K, U) table
    cfg2 = adapt.apply_params(base, {"exit_thr_2": jnp.full((d,), 0.9)})
    np.testing.assert_allclose(np.asarray(cfg2.exit_thr)[:, :, 2], 0.9)
    np.testing.assert_allclose(np.asarray(cfg2.exit_thr)[:, :, 1],
                               np.asarray(base.exit_thr)[:, :, 1])
    with pytest.raises(KeyError):
        adapt.apply_params(base, {"bogus": eta})
    with pytest.raises(KeyError):
        adapt.apply_params(base, {"exit_thr_tx": eta})


def test_apply_params_narrows_persistent_flag():
    """On a persistent harvester the base config takes the Eq. 6 fast path;
    a tuned eta < 1 must re-enable the eta-gated Eq. 7 path (otherwise the
    knob is dead and the search sees a flat objective)."""
    prob = adapt.TuneProblem(task=make_task(), harvesters=(energy.PERSISTENT,),
                             seeds=(0,), horizon=20.0)
    base, _ = prob._base
    assert np.asarray(base.persistent).all()   # measured eta == 1.0 exactly
    d = base.n_devices
    low = adapt.apply_params(base, {"eta": jnp.full((d,), 0.5)})
    assert not np.asarray(low.persistent).any()
    high = adapt.apply_params(base, {"eta": jnp.ones((d,))})
    assert np.asarray(high.persistent).all()


def test_exit_threshold_changes_behaviour(problem):
    """A prohibitive exit threshold forces full execution (more units run,
    different accuracy) — proof the simulator honours the tuned-threshold
    path rather than the precomputed passes table."""
    objective = problem.objective()
    lo = objective({"eta": [0.8], "e_opt_fraction": [0.7],
                    "exit_threshold": [0.0]})[0]
    hi = objective({"eta": [0.8], "e_opt_fraction": [0.7],
                    "exit_threshold": [0.99]})[0]
    assert lo != hi


# --------------------------------------------------------------------------- #
# Acceptance: ES tuning beats the paper-default constants on a seeded
# 3-harvester-pattern grid (the ISSUE-2 criterion).
# --------------------------------------------------------------------------- #


def test_es_tuned_beats_paper_default(problem):
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = problem.score(problem.default_params())
    res = adapt.tune(problem.objective(), space, budget=96, driver="es",
                     seed=0)
    assert res.best_score > default_score, (res, default_score)
    # the winning point must reproduce its score (no tracker bookkeeping
    # drift): re-evaluate outside the search loop
    assert problem.score(res.best_params) == pytest.approx(res.best_score)


def test_es_grad_also_beats_default(problem):
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = problem.score(problem.default_params())
    res = adapt.tune(problem.objective(), space, budget=96, driver="es-grad",
                     seed=1)
    assert res.best_score > default_score


def test_tune_under_chrt_drift_beats_default():
    """Regression for tuning under the fleet CHRT drift axis (previously
    untested): with every device's clock drifting at the CHRTClock
    equivalent rate, the ES driver must still find parameters beating the
    paper defaults on a fixed seed — i.e. the drift field threads through
    the tuned objective rather than silently resetting to exact RTC."""
    drift = CHRTClock().equivalent_drift(30.0)
    assert drift > 0
    prob = adapt.TuneProblem(task=make_task(), harvesters=HARVESTERS[:2],
                             seeds=(0, 1), horizon=30.0, clock_drift=drift)
    base, _ = prob._base
    np.testing.assert_allclose(np.asarray(base.clock_drift), drift,
                               rtol=1e-6)
    space = adapt.SearchSpace.of(eta=(0.05, 1.0),
                                 e_opt_fraction=(0.05, 0.95))
    default_score = prob.score(prob.default_params())
    res = adapt.tune(prob.objective(), space, budget=96, driver="es",
                     seed=0)
    assert res.best_score > default_score, (res, default_score)
    # drift is not a no-op: the same tuned point scores differently on an
    # exact-RTC deployment
    rtc = adapt.TuneProblem(task=make_task(), harvesters=HARVESTERS[:2],
                            seeds=(0, 1), horizon=30.0)
    assert rtc.score(res.best_params) != pytest.approx(res.best_score)


# --------------------------------------------------------------------------- #
# Multi-task tuning: per-task thresholds + task-weighted scalarization.
# --------------------------------------------------------------------------- #


def make_task_set():
    """A deadline-tight task contending with a slack-rich one."""
    return (make_task(task_id=0, period=0.8, deadline=1.2),
            make_task(task_id=1, period=1.6, deadline=4.0))


def test_multitask_objective_and_task_weights():
    tasks = make_task_set()
    agg = adapt.TuneProblem(task=tasks, harvesters=HARVESTERS[:2],
                            seeds=(0,), horizon=20.0)
    weighted = adapt.TuneProblem(task=tasks, harvesters=HARVESTERS[:2],
                                 seeds=(0,), horizon=20.0,
                                 task_weights=(0.9, 0.1))
    base, _ = agg._base
    assert base.period.shape == (agg.n_cells, 2)
    point = {"eta": 0.6, "e_opt_fraction": 0.5}
    s_agg, s_w = agg.score(point), weighted.score(point)
    assert np.isfinite(s_agg) and np.isfinite(s_w)
    # the tight task schedules worse than the slack one, so weighting it
    # 9:1 must move the score away from the aggregate
    assert s_agg != pytest.approx(s_w)
    with pytest.raises(ValueError):
        adapt.TuneProblem(task=tasks, harvesters=HARVESTERS[:2],
                          task_weights=(1.0,))._base


def test_per_task_exit_thresholds_address_one_task():
    """exit_thr_t<k> must move only task k's cells — and changing the
    slack task's threshold must change the simulated outcome without
    touching the other task's threshold column."""
    prob = adapt.TuneProblem(task=make_task_set(), harvesters=HARVESTERS[:2],
                             seeds=(0,), horizon=20.0)
    base, _ = prob._base
    d = base.n_devices
    cfg = adapt.apply_params(base, {"exit_thr_t1": jnp.full((d,), 0.9)})
    np.testing.assert_allclose(np.asarray(cfg.exit_thr)[:, 1, :], 0.9)
    np.testing.assert_allclose(np.asarray(cfg.exit_thr)[:, 0, :],
                               np.asarray(base.exit_thr)[:, 0, :])
    cell = adapt.apply_params(base, {"exit_thr_t0_u2": jnp.full((d,), 0.7)})
    assert np.asarray(cell.exit_thr)[:, 0, 2] == pytest.approx(0.7)
    assert np.asarray(cell.exit_thr)[:, 1, 2] == pytest.approx(
        np.asarray(base.exit_thr)[:, 1, 2])
    # end-to-end: a prohibitive threshold on the slack task changes the
    # objective (the simulator reads the (D, K, U) table per task)
    objective = prob.objective()
    lo = objective({"eta": [0.8], "e_opt_fraction": [0.7],
                    "exit_thr_t1": [0.0]})[0]
    hi = objective({"eta": [0.8], "e_opt_fraction": [0.7],
                    "exit_thr_t1": [0.99]})[0]
    assert lo != hi


# --------------------------------------------------------------------------- #
# Scalarization.
# --------------------------------------------------------------------------- #


def test_scalarized_objective_orders_outcomes():
    # more correct jobs -> higher score; misses penalised when weighted
    a = scalarized_objective(10.0, 20.0)
    b = scalarized_objective(15.0, 20.0)
    assert float(b) > float(a)
    c = scalarized_objective(10.0, 20.0, 5.0, miss_weight=0.5)
    assert float(c) < float(a)
    # batched (D,) inputs keep the device axis
    v = scalarized_objective(jnp.array([10.0, 15.0]), jnp.array([20.0, 20.0]))
    assert v.shape == (2,) and float(v[1]) > float(v[0])
    # zero released jobs doesn't blow up
    assert np.isfinite(float(scalarized_objective(0.0, 0.0)))
