"""Synthetic data generators + pipeline + checkpointing."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.data import (
    batches,
    make_dataset,
    make_lm_tokens,
    make_siamese_pairs,
    make_token_dataset,
)
from repro.train.checkpoint import load_checkpoint, save_checkpoint


def test_dataset_shapes_and_determinism():
    a = make_dataset("mnist", n_train=64, n_test=32, seed=3)
    b = make_dataset("mnist", n_train=64, n_test=32, seed=3)
    np.testing.assert_array_equal(a.x_train, b.x_train)
    assert a.x_train.shape == (64, 28, 28, 1)
    assert a.n_classes == 10
    c = make_dataset("cifar100", n_train=16, n_test=8)
    assert c.x_train.shape == (16, 32, 32, 3)
    assert c.n_classes == 5  # paper: randomized 5-class subsets


def test_environment_shift_changes_distribution():
    base = make_dataset("esc10", n_train=64, n_test=32, seed=1)
    shifted = make_dataset("esc10", n_train=64, n_test=32, seed=1,
                           environment=2)
    assert np.abs(base.x_test - shifted.x_test).mean() > 0.1


def test_siamese_pairs_balanced():
    ds = make_dataset("mnist", n_train=128, n_test=8)
    x1, x2, diff = make_siamese_pairs(ds.x_train, ds.y_train, 200, seed=0)
    assert len(x1) == len(x2) == len(diff) == 200
    assert diff.mean() == pytest.approx(0.5, abs=0.01)


def test_token_dataset_class_signal():
    toks, y = make_token_dataset(64, 32, 4, 128, separability=4.0, seed=0)
    assert toks.shape == (128, 32)
    assert toks.max() < 64
    # class-c sequences concentrate in the class-c vocab slice
    for c in range(4):
        sub = toks[y == c]
        if len(sub) == 0:
            continue
        lo, hi = c * 16, (c + 1) * 16
        frac = ((sub >= lo) & (sub < hi)).mean()
        assert frac > 0.3  # >> uniform 0.25 baseline... strictly above


def test_lm_tokens_short_range_structure():
    toks = make_lm_tokens(50, 128, 32, seed=0)
    nxt = (toks[:, 1:] == (toks[:, :-1] + 1) % 50).mean()
    assert nxt > 0.2  # the injected 30% copy structure


def test_batches_cover_epoch_without_repeats():
    x = np.arange(40)
    y = np.arange(40)
    seen = []
    for bx, _ in batches(x, y, 8, seed=0, epochs=1):
        seen.extend(bx.tolist())
    assert sorted(seen) == list(range(40))


def test_checkpoint_roundtrip(tmp_path, key):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {"b": jax.random.normal(key, (4,), dtype=jnp.bfloat16)},
        "tup": (jnp.ones((2,)), jnp.zeros((3,), jnp.int32)),
    }
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, tree)
    out = load_checkpoint(path, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_checkpoint_model_params_roundtrip(tmp_path, key):
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("xlstm-125m").reduced()
    params = T.init_params(cfg, key)
    path = os.path.join(tmp_path, "model.npz")
    save_checkpoint(path, params)
    out = load_checkpoint(path, jax.eval_shape(lambda: params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(out)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
