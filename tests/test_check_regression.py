"""The CI benchmark-regression gate (`benchmarks/check_regression.py`).

The gate must exit nonzero on an injected beyond-tolerance throughput
drop or deterministic-score drop, stay quiet inside the tolerance bands,
flag structural drift (changed row identities) instead of silently
comparing apples to oranges, and support re-baselining via ``--update``.
"""
from __future__ import annotations

import importlib.util
import io
import json
import pathlib

import pytest


@pytest.fixture(scope="module")
def gate():
    path = (pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
            / "check_regression.py")
    spec = importlib.util.spec_from_file_location("check_regression", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _doc(dsps: float = 1000.0, score: float = 0.3, mode: str = "demo",
         ok: bool = True) -> dict:
    return dict(bench="x", ok=ok, wall_s=1.0, rows={
        "x": [dict(mode=mode, device_steps_per_sec=dsps, score=score)],
    })


def _write(tmp_path, fresh: dict, base: dict):
    fresh_dir = tmp_path / "experiments"
    base_dir = fresh_dir / "baselines"
    base_dir.mkdir(parents=True, exist_ok=True)
    (fresh_dir / "BENCH_x.json").write_text(json.dumps(fresh))
    (base_dir / "BENCH_x.json").write_text(json.dumps(base))
    return fresh_dir, base_dir


def _run(gate, tmp_path, fresh, base, **kw) -> tuple[int, str]:
    fresh_dir, base_dir = _write(tmp_path, fresh, base)
    out = io.StringIO()
    code = gate.check(fresh_dir, base_dir, out=out, **kw)
    return code, out.getvalue()


def test_passes_on_identical_results(gate, tmp_path):
    code, out = _run(gate, tmp_path, _doc(), _doc())
    assert code == 0 and "ok" in out


def test_fails_on_throughput_drop_beyond_tolerance(gate, tmp_path):
    # 10x drop >> the default 0.75 band
    code, out = _run(gate, tmp_path, _doc(dsps=100.0), _doc(dsps=1000.0))
    assert code == 1
    assert "device_steps_per_sec" in out and "FAIL" in out


def test_passes_on_throughput_drop_within_tolerance(gate, tmp_path):
    code, _ = _run(gate, tmp_path, _doc(dsps=600.0), _doc(dsps=1000.0))
    assert code == 0
    # throughput gains never trip the gate
    code, _ = _run(gate, tmp_path, _doc(dsps=5000.0), _doc(dsps=1000.0))
    assert code == 0


def test_fails_on_score_drop_beyond_tolerance(gate, tmp_path):
    code, out = _run(gate, tmp_path, _doc(score=0.25), _doc(score=0.3))
    assert code == 1 and "score" in out
    code, _ = _run(gate, tmp_path, _doc(score=0.2999), _doc(score=0.3))
    assert code == 0


def test_fails_on_structural_drift_and_failed_run(gate, tmp_path):
    code, out = _run(gate, tmp_path, _doc(mode="renamed"), _doc(mode="demo"))
    assert code == 1 and "identity" in out
    code, out = _run(gate, tmp_path, _doc(ok=False), _doc())
    assert code == 1 and "ok=false" in out


def test_fails_on_missing_fresh_artifact(gate, tmp_path):
    fresh_dir, base_dir = _write(tmp_path, _doc(), _doc())
    (fresh_dir / "BENCH_x.json").unlink()
    code = gate.check(fresh_dir, base_dir, out=io.StringIO())
    assert code == 1


def test_update_rebaselines(gate, tmp_path):
    fresh = _doc(dsps=100.0)
    fresh_dir, base_dir = _write(tmp_path, fresh, _doc(dsps=1000.0))
    out = io.StringIO()
    assert gate.check(fresh_dir, base_dir, update=True, out=out) == 0
    assert json.loads((base_dir / "BENCH_x.json").read_text()) == fresh
    assert gate.check(fresh_dir, base_dir, out=io.StringIO()) == 0


def test_update_bootstraps_missing_baseline_dir(gate, tmp_path):
    """--update must work from nothing: no baselines directory yet."""
    fresh_dir = tmp_path / "experiments"
    fresh_dir.mkdir()
    (fresh_dir / "BENCH_x.json").write_text(json.dumps(_doc()))
    base_dir = fresh_dir / "baselines"         # does not exist
    assert gate.check(fresh_dir, base_dir, update=True,
                      out=io.StringIO()) == 0
    assert (base_dir / "BENCH_x.json").exists()
    assert gate.check(fresh_dir, base_dir, out=io.StringIO()) == 0
    # nothing fresh to adopt -> the update is an error, not a silent no-op
    empty = tmp_path / "empty"
    empty.mkdir()
    assert gate.check(empty, base_dir / "nope", update=True,
                      out=io.StringIO()) == 1


def test_committed_baselines_pass_against_themselves(gate):
    """The baselines in the repo are self-consistent: gating them against
    a copy of themselves passes (catches malformed committed artifacts)."""
    base_dir = (pathlib.Path(__file__).resolve().parent.parent
                / "experiments" / "baselines")
    assert sorted(p.name for p in base_dir.glob("BENCH_*.json")), \
        "no committed baselines"
    assert gate.check(base_dir, base_dir, out=io.StringIO()) == 0
