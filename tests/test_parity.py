"""Scalar↔fleet parity harness over randomized multi-task workloads.

Two tiers, now that every discretized frontend runs the ONE step core in
:mod:`repro.core.step`:

* **bit-exact** — :func:`repro.core.scheduler.simulate_stepped` (scalar
  ``lax.scan`` over the step core, no vmap) vs
  :func:`repro.fleet.simulate_fleet` (vmap of the same functions): every
  metric equal, for all four policies x both persistence modes x
  K in {1, 2, 4}.  No tolerances — batching must not change a single
  count, and the segmented runner must be bit-identical to the monolithic
  scan for any segment count.
* **calibrated** — the *event-driven* :func:`repro.core.scheduler.simulate`
  vs the discretized paths agrees only within the documented
  discretization bound (:func:`_workloads.per_task_bound`); those
  comparisons keep their tolerance, everything else is exact.

Workload generation and the tolerance calibration live in
``tests/_workloads.py`` (shared with ``tests/test_fleet.py``).
"""
import jax
import numpy as np
import pytest

from _workloads import (
    DT,
    HORIZON,
    MODES,
    TASK_SET_SEEDS,
    per_task_bound,
    random_task_set,
)
from repro import fleet
from repro.core.scheduler import SimConfig, simulate, simulate_stepped

ALL_POLICIES = ["zygarde", "edf", "edf-m", "rr"]

EXACT_FIELDS = (
    "released", "scheduled", "correct", "deadline_misses", "units_executed",
    "optional_units", "busy_time", "idle_no_energy", "reboots",
    "wasted_reexec",
)


# --------------------------------------------------------------------------- #
# Tier 1: bit-exact — fleet (vmap of core.step) vs simulate_stepped (scalar
# scan of core.step).
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_stepped_fleet_parity_bit_exact(pol, mode, k):
    """The fleet path IS vmap of the step core: every aggregate metric and
    every per-task counter must be exactly equal to the scalar-stepped
    frontend on the shared clock — no calibrated bounds."""
    tasks = random_task_set(TASK_SET_SEEDS[k], k)
    harv, eta = MODES[mode]
    sim = SimConfig(policy=pol, horizon=HORIZON, seed=3)
    stepped = simulate_stepped(tasks, harv, eta, sim=sim, dt=DT)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    d = fleet.simulate_fleet(cfg, statics).device(0)

    for name in EXACT_FIELDS:
        assert getattr(stepped, name) == d[name], name
    for name in ("released", "scheduled", "correct", "misses"):
        np.testing.assert_array_equal(
            np.asarray(getattr(stepped, f"task_{name}")),
            np.asarray(d[f"task_{name}"]), err_msg=f"task_{name}")
    # job conservation per task
    np.testing.assert_array_equal(
        stepped.task_scheduled + stepped.task_misses, stepped.task_released)


@pytest.mark.parametrize("n_segments", [1, 3, 7, 32])
def test_run_segments_bit_identical_to_monolithic(n_segments):
    """Chunked execution over the checkpointable carry must reproduce the
    monolithic scan exactly, for any segment count (including ones that do
    not divide the step count)."""
    harv, _ = MODES["intermittent"]
    grid = fleet.SweepGrid(
        task=random_task_set(TASK_SET_SEEDS[2], 2),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 1.0),
        harvesters=(harv,),
        horizon=HORIZON,
        dt=DT,
    )
    cfg, statics, _ = fleet.build(grid)
    mono = fleet.simulate_fleet(cfg, statics)
    seg, carry = fleet.run_segments(cfg, statics, n_segments)
    for name in mono._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(mono, name)), np.asarray(getattr(seg, name)),
            err_msg=name)
    # the returned carry is the end-of-horizon state: finalizing it again
    # must be idempotent
    again = fleet.finalize_fleet(cfg, carry, statics)
    np.testing.assert_array_equal(np.asarray(again.correct),
                                  np.asarray(mono.correct))


def test_run_segments_carry_resume():
    """Checkpoint/resume through the public API: run the first half on a
    half-horizon statics, then resume the returned carry with
    ``start_step`` — bit-identical to one uninterrupted run.  The clock is
    ``t = step * dt`` and the carry holds absolute release/deadline times,
    so the resumed run must continue the step index, not restart at 0."""
    import dataclasses

    harv, eta = MODES["intermittent"]
    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=3)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    full, _ = fleet.run_segments(cfg, statics, 4)

    half = dataclasses.replace(statics, horizon=HORIZON / 2)
    _, carry = fleet.run_segments(cfg, half, 2)
    res, _ = fleet.run_segments(cfg, statics, 2, carry=carry,
                                start_step=half.n_steps)
    for name in res._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, name)), np.asarray(getattr(full, name)),
            err_msg=name)
    with pytest.raises(ValueError, match="start_step"):
        fleet.run_segments(cfg, statics, 1, carry=carry,
                           start_step=statics.n_steps + 1)


# --------------------------------------------------------------------------- #
# Fused kernel mode: the whole time loop inside ONE pallas_call
# (repro.kernels.fleet_step) must be bit-exact against the vmap scan —
# same matrix as the stepped/fleet tier, plus segmented resume and the
# one-call-per-segment dispatch shape.
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_fused_fleet_parity_bit_exact(pol, mode, k):
    """mode="fused" runs the entire admit->expire->pick->apply loop inside
    the kernel; the kernel body IS core.step.device_step, so every result
    field must be exactly equal to the vmap scan — no tolerances."""
    tasks = random_task_set(TASK_SET_SEEDS[k], k)
    harv, eta = MODES[mode]
    sim = SimConfig(policy=pol, horizon=HORIZON, seed=3)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    ref = fleet.simulate_fleet(cfg, statics)
    fused = fleet.simulate_fleet(cfg, statics, mode="fused")
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(fused, name)),
            err_msg=name)


def test_fused_run_segments_resume_mid_horizon():
    """Fused segmented execution with checkpoint/resume: run half the
    horizon fused, resume the carry at ``start_step``, and land bit-exactly
    on the vmap run — results AND the end-of-horizon carry pytree."""
    import dataclasses

    harv, eta = MODES["intermittent"]
    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=3)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    full, cfull = fleet.run_segments(cfg, statics, 3, mode="vmap")

    half = dataclasses.replace(statics, horizon=HORIZON / 2)
    _, carry = fleet.run_segments(cfg, half, 2, mode="fused")
    res, cf = fleet.run_segments(cfg, statics, 2, carry=carry,
                                 start_step=half.n_steps, mode="fused")
    for name in res._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res, name)), np.asarray(getattr(full, name)),
            err_msg=name)
    for name in cf._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(cf, name)), np.asarray(getattr(cfull, name)),
            err_msg=f"carry.{name}")


def test_fused_odd_device_count_padded_tiles():
    """An odd fleet size on a small block (D=5, block_d=2 -> Dp=6) pads the
    device axis; padded all-zero devices never release work and their rows
    are sliced off — real devices stay bit-exact vs the vmap scan."""
    import jax.numpy as jnp

    from repro.kernels import ops

    harv, eta = MODES["intermittent"]
    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=3)
    cfg1, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    cfg = jax.tree.map(lambda x: jnp.concatenate([x] * 5, axis=0), cfg1)
    ref = fleet.simulate_fleet(cfg, statics)
    carry = fleet.init_fleet(cfg, statics)
    carry = ops.fleet_fused_steps(cfg, carry, jnp.int32(0), statics=statics,
                                  n_steps=statics.n_steps, block_d=2)
    fused = fleet.finalize_fleet(cfg, carry, statics)
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(fused, name)),
            err_msg=name)


def _walk_eqns(jaxpr, stop_inside=("pallas_call",)):
    """Yield every eqn in ``jaxpr`` and its sub-jaxprs, without descending
    into the params of primitives named in ``stop_inside``."""
    def subs(val):
        if hasattr(val, "jaxpr"):          # ClosedJaxpr
            return [val.jaxpr]
        if hasattr(val, "eqns"):           # raw Jaxpr
            return [val]
        if isinstance(val, (list, tuple)):
            return [j for v in val for j in subs(v)]
        return []

    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name in stop_inside:
            continue
        for val in eqn.params.values():
            for sub in subs(val):
                yield from _walk_eqns(sub, stop_inside)


def test_fused_segment_is_one_pallas_call():
    """The fused mode's whole point: a segment traces to exactly ONE
    pallas_call with NO scan/while around it (the time loop lives inside
    the kernel) — vs the per-step pallas mode, whose segment is a scan
    wrapping a per-step kernel dispatch."""
    from repro.fleet.simulator import _scan_steps
    from repro.kernels import ops

    harv, eta = MODES["intermittent"]
    tasks = random_task_set(TASK_SET_SEEDS[1], 1)
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=3)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    carry = fleet.init_fleet(cfg, statics)

    jaxpr = jax.make_jaxpr(
        lambda c, s, i0: ops.fleet_fused_steps(
            c, s, i0, statics=statics, n_steps=17)
    )(cfg, carry, 0)
    names = [e.primitive.name for e in _walk_eqns(jaxpr.jaxpr)]
    assert names.count("pallas_call") == 1
    assert "scan" not in names and "while" not in names

    # the per-step kernel mode, for contrast: one scan, kernel inside it
    jaxpr_step = jax.make_jaxpr(
        lambda c, s, i0: _scan_steps(c, s, i0, statics, 17, True)
    )(cfg, carry, 0)
    names_step = [e.primitive.name for e in _walk_eqns(
        jaxpr_step.jaxpr, stop_inside=())]
    assert "scan" in names_step


# --------------------------------------------------------------------------- #
# Tier 2: calibrated — the event-driven scalar simulator vs the stepped
# paths (the only comparison that keeps tolerances).
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("pol", ALL_POLICIES)
def test_scalar_fleet_task_parity(pol, mode, k):
    tasks = random_task_set(TASK_SET_SEEDS[k], k)
    harv, eta = MODES[mode]
    sim = SimConfig(policy=pol, horizon=HORIZON, seed=3)
    scalar = simulate(tasks, harv, eta, sim=sim)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    d = fleet.simulate_fleet(cfg, statics).device(0)

    # the release schedule is deterministic: per-task released must be exact
    np.testing.assert_array_equal(scalar.task_released, d["task_released"])
    assert scalar.released == d["released"]

    bound = per_task_bound(scalar.task_released, mode)
    for name in ("scheduled", "correct", "misses"):
        s = np.asarray(getattr(scalar, f"task_{name}"), np.int64)
        f = np.asarray(d[f"task_{name}"], np.int64)
        assert (np.abs(s - f) <= bound).all(), (
            f"per-task {name} diverged beyond the discretization bound: "
            f"scalar={s.tolist()} fleet={f.tolist()} bound={bound.tolist()}")

    # both paths conserve jobs per task: scheduled + missed == released
    np.testing.assert_array_equal(
        np.asarray(scalar.task_scheduled) + np.asarray(scalar.task_misses),
        np.asarray(scalar.task_released))
    np.testing.assert_array_equal(
        np.asarray(d["task_scheduled"]) + np.asarray(d["task_misses"]),
        np.asarray(d["task_released"]))


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
def test_fleet_task_breakdown_sums_to_aggregates(k):
    """(D, K) per-task counters must sum to the (D,) aggregates on a mixed
    sweep (policies × etas), for every device."""
    harv, _ = MODES["intermittent"]
    res, meta = fleet.sweep(fleet.SweepGrid(
        task=random_task_set(TASK_SET_SEEDS[k], k),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 1.0),
        harvesters=(harv,),
        horizon=HORIZON,
        dt=DT,
    ))
    assert all(m["n_tasks"] == k for m in meta)
    for task_name, agg_name in (
        ("task_released", "released"),
        ("task_scheduled", "scheduled"),
        ("task_correct", "correct"),
        ("task_misses", "deadline_misses"),
        ("task_units", "units_executed"),
        ("task_optional", "optional_units"),
    ):
        per_task = np.asarray(getattr(res, task_name))
        assert per_task.shape == (len(meta), k)
        np.testing.assert_array_equal(
            per_task.sum(axis=1), np.asarray(getattr(res, agg_name)),
            err_msg=task_name)


def test_pallas_kernel_matches_jnp_on_task_sets():
    """The task-dimension-aware Pallas pick must stay bit-identical to the
    jnp pick on a K=4 multi-policy grid (including the in-kernel rr task
    rotation)."""
    harv, _ = MODES["intermittent"]
    grid = fleet.SweepGrid(
        task=random_task_set(TASK_SET_SEEDS[4], 4),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 1.0),
        harvesters=(harv,),
        horizon=HORIZON,
        dt=DT,
    )
    cfg, statics, _ = fleet.build(grid)
    ref = fleet.simulate_fleet(cfg, statics, mode="vmap")
    ker = fleet.simulate_fleet(cfg, statics, mode="pallas")
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(ker, name)),
            err_msg=name)


def test_rr_rotation_horizon_guard():
    """The rr task-rotation weight only dominates releases below
    RR_TASK_W seconds of horizon; multi-task rr grids beyond it must fail
    loudly instead of silently inverting the rotation."""
    from repro.core.policy import RR_TASK_W

    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, _ = MODES["persistent"]
    with pytest.raises(ValueError, match="rr task rotation"):
        fleet.build(fleet.SweepGrid(task=tasks, policies=("rr",),
                                    harvesters=(harv,), horizon=RR_TASK_W))
    # single-task rr (rank identically 0) and long-horizon non-rr are fine
    fleet.build(fleet.SweepGrid(task=tasks[:1], policies=("rr",),
                                harvesters=(harv,), horizon=RR_TASK_W,
                                dt=DT))
    fleet.build(fleet.SweepGrid(task=tasks, policies=("edf",),
                                harvesters=(harv,), horizon=RR_TASK_W,
                                dt=DT))


def test_sim_result_dicts_json_serializable():
    """All three result exports must survive json.dumps with the per-task
    arrays included: SimResult.as_dict (launch/serve.py dumps it verbatim),
    FleetResult.device(i), and the whole-fleet FleetResult.as_dict
    (benchmarks/run.py writes it into BENCH_<name>.json)."""
    import json

    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, eta = MODES["persistent"]
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=0)
    scalar = simulate(tasks, harv, eta, sim=sim)
    json.dumps(scalar.as_dict())
    stepped = simulate_stepped(tasks, harv, eta, sim=sim, dt=DT)
    json.dumps(stepped.as_dict())
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    res = fleet.simulate_fleet(cfg, statics)
    json.dumps(res.device(0))
    d = json.loads(json.dumps(res.as_dict()))   # fleet-level export
    assert d["task_scheduled"] == np.asarray(res.task_scheduled).tolist()
    assert d["released"] == np.asarray(res.released).tolist()


def test_scalar_per_task_metrics_consistent():
    """The scalar simulator's per-task counters sum to its aggregates."""
    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, eta = MODES["intermittent"]
    res = simulate(tasks, harv, eta,
                   sim=SimConfig(policy="zygarde", horizon=HORIZON, seed=5))
    assert int(res.task_released.sum()) == res.released
    assert int(res.task_scheduled.sum()) == res.scheduled
    assert int(res.task_correct.sum()) == res.correct
    assert int(res.task_misses.sum()) == res.deadline_misses
