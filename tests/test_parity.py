"""Scalar↔fleet parity harness over randomized multi-task workloads.

A seeded generator draws task *sets* (K periodic DNN streams with
heterogeneous unit counts, periods, deadlines and utility profiles) plus a
harvester trace, runs the SAME configuration through the scalar
event-driven :func:`repro.core.scheduler.simulate` and the vectorized
:func:`repro.fleet.simulate_fleet`, and asserts the per-task
on-time/accuracy/drop counts agree within the timestep-discretization
bound — parametrized over all four policies and both persistence modes,
for K ∈ {1, 2, 4}.

Tolerances are calibrated against the fidelity gap documented in
``repro.fleet.simulator``: the fleet path quantizes execution to ``dt``
and drains fragment energy continuously, so energy-starved boundary jobs
can land on the other side of a deadline.  Empirically (48 seeded runs per
mode) the per-task deviation stays ≤ 1 job under persistent power and
≤ 3 jobs (≤ 25% of a task's releases) under intermittent power; the bounds
below add headroom on top while still failing loudly on any systematic
task-row mix-up (which mis-counts whole streams, not boundary jobs).

Workload note: unit times are quantized to multiples of ``4 * DT`` so one
fleet timestep is exactly one fragment of every task — the regime the
simulator documents as its fidelity envelope.
"""
import numpy as np
import pytest

from repro import fleet
from repro.core import energy
from repro.core.scheduler import JobProfile, SimConfig, TaskSpec, simulate

DT = 0.005          # fleet timestep; unit times are multiples of 4*DT
HORIZON = 12.0
TASK_SET_SEEDS = {1: 11, 2: 22, 4: 44}

# (harvester, eta) per persistence mode: `persistent` takes the Eq. 6 zeta
# fast path (eta = 1, p_stay_on = 1), `intermittent` the eta-gated Eq. 7
MODES = {
    "persistent": (energy.Harvester("battery", 1.0, 0.0, 10.0), 1.0),
    "intermittent": (energy.Harvester("rf", 0.93, 0.93, 0.07), 0.7),
}


def random_task_set(seed: int, k: int) -> list[TaskSpec]:
    """K tasks with distinct periods/deadlines/depths; full-execution
    utilization of the whole set ~0.6 so even EDF (no early exit) is loaded
    but not hopeless."""
    rng = np.random.default_rng(seed)
    tasks = []
    for tid in range(k):
        n_units = int(rng.integers(3, 6))
        period = float(rng.choice([0.8, 1.0, 1.2, 1.6]))
        deadline = period * float(rng.uniform(1.5, 2.5))
        grains = max(1, round(0.6 * period / (k * n_units) / (4 * DT)))
        unit_t = grains * 4 * DT
        unit_e = float(rng.uniform(4e-3, 1e-2))
        exit_at = int(rng.integers(0, n_units - 1))
        correct_from = int(rng.integers(0, n_units))
        n_jobs = int(np.ceil(HORIZON / period)) + 1
        profiles = []
        for _ in range(n_jobs):
            margins = np.sort(rng.uniform(0.05, 0.6, n_units))
            passes = np.zeros(n_units, bool)
            passes[exit_at:] = True
            correct = np.zeros(n_units, bool)
            correct[correct_from:] = True
            profiles.append(JobProfile(margins, passes, correct))
        tasks.append(TaskSpec(
            task_id=tid, period=period, deadline=deadline,
            unit_time=np.full(n_units, unit_t),
            unit_energy=np.full(n_units, unit_e),
            profiles=profiles,
        ))
    return tasks


def _per_task_bound(released, mode: str) -> np.ndarray:
    rel = np.maximum(np.asarray(released, np.float64), 1.0)
    if mode == "persistent":
        return np.maximum(2.0, np.ceil(0.1 * rel))
    return np.maximum(3.0, np.ceil(0.35 * rel))


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
@pytest.mark.parametrize("mode", sorted(MODES))
@pytest.mark.parametrize("pol", ["zygarde", "edf", "edf-m", "rr"])
def test_scalar_fleet_task_parity(pol, mode, k):
    tasks = random_task_set(TASK_SET_SEEDS[k], k)
    harv, eta = MODES[mode]
    sim = SimConfig(policy=pol, horizon=HORIZON, seed=3)
    scalar = simulate(tasks, harv, eta, sim=sim)
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    d = fleet.simulate_fleet(cfg, statics).device(0)

    # the release schedule is deterministic: per-task released must be exact
    np.testing.assert_array_equal(scalar.task_released, d["task_released"])
    assert scalar.released == d["released"]

    bound = _per_task_bound(scalar.task_released, mode)
    for name in ("scheduled", "correct", "misses"):
        s = np.asarray(getattr(scalar, f"task_{name}"), np.int64)
        f = np.asarray(d[f"task_{name}"], np.int64)
        assert (np.abs(s - f) <= bound).all(), (
            f"per-task {name} diverged beyond the discretization bound: "
            f"scalar={s.tolist()} fleet={f.tolist()} bound={bound.tolist()}")

    # both paths conserve jobs per task: scheduled + missed == released
    np.testing.assert_array_equal(
        np.asarray(scalar.task_scheduled) + np.asarray(scalar.task_misses),
        np.asarray(scalar.task_released))
    np.testing.assert_array_equal(
        np.asarray(d["task_scheduled"]) + np.asarray(d["task_misses"]),
        np.asarray(d["task_released"]))


@pytest.mark.parametrize("k", sorted(TASK_SET_SEEDS))
def test_fleet_task_breakdown_sums_to_aggregates(k):
    """(D, K) per-task counters must sum to the (D,) aggregates on a mixed
    sweep (policies × etas), for every device."""
    harv, _ = MODES["intermittent"]
    res, meta = fleet.sweep(fleet.SweepGrid(
        task=random_task_set(TASK_SET_SEEDS[k], k),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 1.0),
        harvesters=(harv,),
        horizon=HORIZON,
        dt=DT,
    ))
    assert all(m["n_tasks"] == k for m in meta)
    for task_name, agg_name in (
        ("task_released", "released"),
        ("task_scheduled", "scheduled"),
        ("task_correct", "correct"),
        ("task_misses", "deadline_misses"),
        ("task_units", "units_executed"),
        ("task_optional", "optional_units"),
    ):
        per_task = np.asarray(getattr(res, task_name))
        assert per_task.shape == (len(meta), k)
        np.testing.assert_array_equal(
            per_task.sum(axis=1), np.asarray(getattr(res, agg_name)),
            err_msg=task_name)


def test_pallas_kernel_matches_jnp_on_task_sets():
    """The task-dimension-aware Pallas pick must stay bit-identical to the
    jnp pick on a K=4 multi-policy grid (including the in-kernel rr task
    rotation)."""
    harv, _ = MODES["intermittent"]
    grid = fleet.SweepGrid(
        task=random_task_set(TASK_SET_SEEDS[4], 4),
        policies=("zygarde", "edf", "edf-m", "rr"),
        etas=(0.5, 1.0),
        harvesters=(harv,),
        horizon=HORIZON,
        dt=DT,
    )
    cfg, statics, _ = fleet.build(grid)
    ref = fleet.simulate_fleet(cfg, statics, use_pallas=False)
    ker = fleet.simulate_fleet(cfg, statics, use_pallas=True)
    for name in ref._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(ref, name)), np.asarray(getattr(ker, name)),
            err_msg=name)


def test_rr_rotation_horizon_guard():
    """The rr task-rotation weight only dominates releases below
    RR_TASK_W seconds of horizon; multi-task rr grids beyond it must fail
    loudly instead of silently inverting the rotation."""
    from repro.core.policy import RR_TASK_W

    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, _ = MODES["persistent"]
    with pytest.raises(ValueError, match="rr task rotation"):
        fleet.build(fleet.SweepGrid(task=tasks, policies=("rr",),
                                    harvesters=(harv,), horizon=RR_TASK_W))
    # single-task rr (rank identically 0) and long-horizon non-rr are fine
    fleet.build(fleet.SweepGrid(task=tasks[:1], policies=("rr",),
                                harvesters=(harv,), horizon=RR_TASK_W,
                                dt=DT))
    fleet.build(fleet.SweepGrid(task=tasks, policies=("edf",),
                                harvesters=(harv,), horizon=RR_TASK_W,
                                dt=DT))


def test_sim_result_dicts_json_serializable():
    """Both result containers must survive json.dumps with the per-task
    arrays included (launch/serve.py dumps SimResult.as_dict verbatim)."""
    import json

    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, eta = MODES["persistent"]
    sim = SimConfig(policy="zygarde", horizon=HORIZON, seed=0)
    scalar = simulate(tasks, harv, eta, sim=sim)
    json.dumps(scalar.as_dict())
    cfg, statics = fleet.from_sim_config(tasks, harv, eta, sim=sim, dt=DT)
    json.dumps(fleet.simulate_fleet(cfg, statics).device(0))


def test_scalar_per_task_metrics_consistent():
    """The scalar simulator's new per-task counters sum to its aggregates."""
    tasks = random_task_set(TASK_SET_SEEDS[2], 2)
    harv, eta = MODES["intermittent"]
    res = simulate(tasks, harv, eta,
                   sim=SimConfig(policy="zygarde", horizon=HORIZON, seed=5))
    assert int(res.task_released.sum()) == res.released
    assert int(res.task_scheduled.sum()) == res.scheduled
    assert int(res.task_correct.sum()) == res.correct
    assert int(res.task_misses.sum()) == res.deadline_misses
